//! CLI integration tests across a real process boundary: the built `tuna`
//! binary (`CARGO_BIN_EXE_tuna`) is spawned as separate OS processes for
//! the whole multi-machine story — sharded `tune-net --save-cache` runs,
//! `merge-caches`, then a `serve` daemon warm-loaded from the merged file
//! answered by `query` over a real socket. `merge-caches`, `serve` and
//! `query` have no other coverage at this level; everything here crosses
//! argv, exit codes, stdout and TCP, not library calls.

use std::io::{BufRead, BufReader};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_tuna")
}

fn temp_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("tuna_cli_{tag}_{}.json", std::process::id()))
}

/// The search parameters every stage of the test must share — the
/// schedule-cache address includes them, so a `query` with different
/// parameters would (correctly) miss the tuned entries.
const ES_FLAGS: [&str; 6] = ["--pop", "8", "--iters", "4", "--seed", "11"];

/// Kill the daemon if the test panics before the clean shutdown path.
struct DaemonGuard(Option<Child>);

impl DaemonGuard {
    /// Hand the child back for a clean `wait`.
    fn take(&mut self) -> Child {
        self.0.take().expect("daemon already taken")
    }
}

impl Drop for DaemonGuard {
    fn drop(&mut self) {
        if let Some(mut child) = self.0.take() {
            let _ = child.kill();
            let _ = child.wait();
        }
    }
}

fn run_ok(args: &[&str]) -> String {
    let out = Command::new(bin())
        .args(args)
        .stderr(Stdio::null())
        .output()
        .expect("failed to spawn tuna");
    assert!(
        out.status.success(),
        "tuna {} exited with {:?}",
        args.join(" "),
        out.status.code()
    );
    String::from_utf8_lossy(&out.stdout).into_owned()
}

#[test]
fn sharded_tune_merge_serve_query_across_process_boundaries() {
    let w0 = temp_path("w0");
    let w1 = temp_path("w1");
    let merged = temp_path("merged");

    // two independent sharded tuning runs persist their caches — as two
    // machines would. Identical inputs, so the merge below exercises the
    // key-clash (combine) path end to end.
    for out in [&w0, &w1] {
        let mut args = vec![
            "tune-net",
            "--net",
            "bert_base",
            "--target",
            "graviton2",
            "--shards",
            "2",
        ];
        args.extend(ES_FLAGS);
        let out_s = out.display().to_string();
        args.extend(["--save-cache", out_s.as_str()]);
        run_ok(&args);
        assert!(out.exists(), "{} was not written", out.display());
    }

    // fold the two worker files into one serving cache
    let inputs = format!("{},{}", w0.display(), w1.display());
    let merged_s = merged.display().to_string();
    let stdout =
        run_ok(&["merge-caches", "--inputs", inputs.as_str(), "--out", merged_s.as_str()]);
    assert!(stdout.contains("merged"), "merge-caches reported nothing: {stdout}");
    let _ = std::fs::remove_file(&w0);
    let _ = std::fs::remove_file(&w1);

    // serve the merged file on an ephemeral port (a separate process)
    let mut daemon = DaemonGuard(Some(
        Command::new(bin())
            .args(["serve", "--targets", "graviton2", "--port", "0"])
            .args(["--load-cache", merged_s.as_str()])
            .stdout(Stdio::piped())
            .stderr(Stdio::null())
            .spawn()
            .expect("failed to spawn serve daemon"),
    ));
    let port = {
        let stdout = daemon.0.as_mut().unwrap().stdout.take().expect("no stdout pipe");
        let mut line = String::new();
        BufReader::new(stdout).read_line(&mut line).expect("daemon stdout unreadable");
        // "listening on 127.0.0.1:PORT"
        line.trim()
            .rsplit(':')
            .next()
            .and_then(|p| p.parse::<u16>().ok())
            .unwrap_or_else(|| panic!("no port in daemon banner {line:?}"))
    };
    let port_s = port.to_string();

    // a bert_base task, queried with the same search parameters the
    // tune-net runs used: served from the merged cache, search-free
    let mut args = vec![
        "query",
        "--port",
        port_s.as_str(),
        "--target",
        "graviton2",
        "--op",
        "matmul:128x768x768",
    ];
    args.extend(ES_FLAGS);
    let tuned = run_ok(&args);
    assert!(
        tuned.contains("\"cache_hit\":true"),
        "query was not served from the merged cache: {tuned}"
    );
    assert!(tuned.contains("\"evaluations\":0"), "served query evaluated: {tuned}");

    // the whole network in one batched tune_net exchange: every op is
    // covered by the merged cache, so the batch is all hits and exit 0
    let mut args = vec![
        "query",
        "--port",
        port_s.as_str(),
        "--target",
        "graviton2",
        "--net",
        "bert_base",
    ];
    args.extend(ES_FLAGS);
    let batched = run_ok(&args);
    assert!(batched.contains("\"type\":\"tuned_net\""), "not a batch response: {batched}");
    assert!(!batched.contains("\"cache_hit\":false"), "batched query searched: {batched}");
    assert!(!batched.contains("\"ok\":false"), "an op inside the batch failed: {batched}");

    // the daemon performed zero searches for it
    let stats = run_ok(&["query", "--port", port_s.as_str(), "--stats"]);
    assert!(stats.contains("\"searches\":0"), "daemon searched: {stats}");

    // the metrics exposition is scrape-shaped on stdout and counted the
    // traffic above (2 tunes... counting is exact-tested in serve_e2e)
    let metrics = run_ok(&["query", "--port", port_s.as_str(), "--metrics"]);
    assert!(
        metrics.contains("# TYPE tuna_serve_requests_total counter"),
        "not an exposition: {metrics}"
    );
    assert!(metrics.contains("tuna_serve_requests_total{cmd=\"tune_net\"} 1"), "{metrics}");

    // a fused-epilogue op through the same argv → wire → daemon path:
    // the `+bias_relu` suffix addresses its own cache entry — one cold
    // search, then a warm search-free hit, across process boundaries
    let mut args = vec![
        "query",
        "--port",
        port_s.as_str(),
        "--target",
        "graviton2",
        "--op",
        "matmul:16x16x16+bias_relu",
    ];
    args.extend(ES_FLAGS);
    let cold = run_ok(&args);
    assert!(cold.contains("\"cache_hit\":false"), "fused op was pre-cached: {cold}");
    assert!(
        cold.contains("\"epilogue\":\"bias_relu\""),
        "response echo lost the epilogue: {cold}"
    );
    let warm = run_ok(&args);
    assert!(warm.contains("\"cache_hit\":true"), "fused re-query missed the cache: {warm}");
    assert!(warm.contains("\"evaluations\":0"), "fused warm hit evaluated: {warm}");

    // an unknown epilogue suffix is a clean argv-level error
    let bad_op = "matmul:8x8x8+gelu";
    let bad = Command::new(bin())
        .args(["query", "--port", port_s.as_str(), "--target", "graviton2", "--op", bad_op])
        .output()
        .expect("failed to spawn query");
    assert!(!bad.status.success(), "unknown epilogue suffix exited 0");
    assert!(
        String::from_utf8_lossy(&bad.stderr).contains("epilogue"),
        "unhelpful suffix error: {}",
        String::from_utf8_lossy(&bad.stderr)
    );

    // a target the daemon does not serve is a clean non-zero exit
    let unserved = Command::new(bin())
        .args(["query", "--port", port_s.as_str(), "--target", "v100", "--op", "matmul:8x8x8"])
        .output()
        .expect("failed to spawn query");
    assert!(!unserved.status.success(), "unserved-target query exited 0");
    assert!(
        String::from_utf8_lossy(&unserved.stderr).contains("unknown_target"),
        "missing typed code: {}",
        String::from_utf8_lossy(&unserved.stderr)
    );

    // graceful shutdown via the socket; the daemon process exits 0
    run_ok(&["query", "--port", port_s.as_str(), "--shutdown"]);
    let status = daemon.take().wait().expect("daemon did not exit");
    assert!(status.success(), "daemon exited with {:?}", status.code());
    let _ = std::fs::remove_file(&merged);
}

/// `train-scorer` across the process boundary: two runs with the same
/// target/scorer/seed write byte-identical model files, a different seed
/// writes a different model, and `tune-op --scorer-file` both loads the
/// artifact and rejects a target mismatch with a clean non-zero exit.
#[test]
fn train_scorer_is_byte_deterministic_and_loads_for_tuning() {
    let a = temp_path("scorer_a");
    let b = temp_path("scorer_b");
    let other_seed = temp_path("scorer_seed9");

    for out in [&a, &b] {
        let out_s = out.display().to_string();
        let stdout = run_ok(&[
            "train-scorer",
            "--target",
            "graviton2",
            "--scorer",
            "quadratic",
            "--seed",
            "7",
            "--out",
            out_s.as_str(),
        ]);
        assert!(stdout.contains("quadratic"), "train-scorer reported nothing: {stdout}");
    }
    let bytes_a = std::fs::read(&a).expect("first model file missing");
    let bytes_b = std::fs::read(&b).expect("second model file missing");
    assert_eq!(bytes_a, bytes_b, "same seed produced different model files");
    let _ = std::fs::remove_file(&b);

    let other_s = other_seed.display().to_string();
    run_ok(&[
        "train-scorer",
        "--target",
        "graviton2",
        "--scorer",
        "quadratic",
        "--seed",
        "9",
        "--out",
        other_s.as_str(),
    ]);
    let bytes_seed9 = std::fs::read(&other_seed).expect("seed-9 model file missing");
    assert_ne!(bytes_a, bytes_seed9, "seed is not reaching the training pipeline");
    let _ = std::fs::remove_file(&other_seed);

    // the trained artifact drives a tune
    let a_s = a.display().to_string();
    let mut args =
        vec!["tune-op", "--op", "matmul:32x32x32", "--target", "graviton2"];
    args.extend(["--scorer-file", a_s.as_str()]);
    args.extend(ES_FLAGS);
    let tuned = run_ok(&args);
    assert!(tuned.contains("GF/s"), "tune-op under the scorer file reported nothing: {tuned}");

    // the file records its target; tuning another target with it must fail
    let mismatch = Command::new(bin())
        .args(["tune-op", "--op", "matmul:32x32x32", "--target", "xeon"])
        .args(["--scorer-file", a_s.as_str()])
        .output()
        .expect("failed to spawn tune-op");
    assert!(!mismatch.status.success(), "target-mismatched scorer file exited 0");
    assert!(
        String::from_utf8_lossy(&mismatch.stderr).contains("trained for"),
        "unhelpful mismatch error: {}",
        String::from_utf8_lossy(&mismatch.stderr)
    );
    let _ = std::fs::remove_file(&a);

    // an unknown scorer name is a clean argv-level error
    let bad = Command::new(bin())
        .args(["tune-op", "--op", "matmul:8x8x8", "--target", "graviton2", "--scorer", "mlp"])
        .output()
        .expect("failed to spawn tune-op");
    assert!(!bad.status.success(), "unknown scorer name exited 0");
    assert!(
        String::from_utf8_lossy(&bad.stderr).contains("mlp"),
        "unhelpful scorer error: {}",
        String::from_utf8_lossy(&bad.stderr)
    );
}

#[test]
fn query_against_a_dead_port_fails_cleanly() {
    // port 1 on loopback is never listening in CI containers
    let out = Command::new(bin())
        .args(["query", "--port", "1", "--stats"])
        .output()
        .expect("failed to spawn query");
    assert!(!out.status.success(), "query to a dead port exited 0");
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("connect"),
        "unhelpful connect error: {}",
        String::from_utf8_lossy(&out.stderr)
    );
}
