//! Cross-backend conformance suite for the [`tuna::codegen::Lowering`]
//! trait — the contract every backend must satisfy to plug into the
//! tune → cache → shard → serve stack.
//!
//! The suite is table-driven: one [`BackendRow`] per `TargetKind`. Adding
//! a backend to the crate means adding exactly one row here (the
//! table↔enum coverage test fails until you do), after which every
//! invariant below — schedule totality, flops preservation, lowering
//! well-formedness, feature dimensional stability, cache round-trip
//! bit-identity — runs against the new backend for free.

use tuna::codegen::{self, Lowering};
use tuna::coordinator::{Coordinator, Strategy};
use tuna::eval::ScheduleCache;
use tuna::isa::TargetKind;
use tuna::search::EsParams;
use tuna::tir::ops::{figure_op_suite, Epilogue, OpSpec};
use tuna::transform::{self, ScheduleConfig};

/// One backend's expected conformance profile. `family` pins the trait's
/// self-description; `expects_launch` pins whether lowered programs carry
/// a GPU launch config; `promises_exact_flops` pins whether the scheduled
/// IR's `total_flops` equals `op.flops()` exactly (GPU templates insert
/// explicit copy/staging statements, so they promise ≥ instead).
struct BackendRow {
    kind: TargetKind,
    family: &'static str,
    expects_launch: bool,
    promises_exact_flops: bool,
}

const TABLE: [BackendRow; 6] = [
    BackendRow {
        kind: TargetKind::XeonPlatinum8124M,
        family: "cpu",
        expects_launch: false,
        promises_exact_flops: true,
    },
    BackendRow {
        kind: TargetKind::Graviton2,
        family: "cpu",
        expects_launch: false,
        promises_exact_flops: true,
    },
    BackendRow {
        kind: TargetKind::CortexA53,
        family: "cpu",
        expects_launch: false,
        promises_exact_flops: true,
    },
    BackendRow {
        kind: TargetKind::TeslaV100,
        family: "gpu",
        expects_launch: true,
        promises_exact_flops: false,
    },
    BackendRow {
        kind: TargetKind::JetsonXavier,
        family: "gpu",
        expects_launch: true,
        promises_exact_flops: false,
    },
    BackendRow {
        kind: TargetKind::SiFiveU74,
        family: "riscv",
        expects_launch: false,
        promises_exact_flops: true,
    },
];

fn tiny_es() -> EsParams {
    EsParams { population: 10, iterations: 5, k: 8, seed: 31, ..Default::default() }
}

/// A small spread of configs per space: the default plus grid-strided
/// samples, enough to exercise tiling/unroll/vectorize variation without
/// walking whole spaces.
fn sample_cfgs(lw: &dyn Lowering, op: &OpSpec, n: u64) -> Vec<ScheduleConfig> {
    let space = lw.space(op);
    let mut cfgs = vec![space.default_config()];
    let n = n.min(space.size()).max(1);
    for i in 0..n {
        cfgs.push(space.from_index(i * space.size() / n));
    }
    cfgs
}

/// The op matrix: every figure-suite shape, re-fused with every epilogue
/// it supports (the suite itself mixes epilogues; re-fusing makes the
/// coverage exhaustive rather than incidental).
fn op_matrix() -> Vec<OpSpec> {
    let mut ops = Vec::new();
    let mut seen = std::collections::BTreeSet::new();
    for op in figure_op_suite() {
        let base = op.unfused();
        for e in Epilogue::ALL {
            if let Some(fused) = base.with_epilogue(e) {
                if seen.insert(fused.cache_key()) {
                    ops.push(fused);
                }
            }
        }
    }
    ops
}

/// The table and the enum must cover each other exactly — a new
/// `TargetKind` without a conformance row (or a stale row) fails here,
/// which is the mechanism that makes "new backend = one table row" true.
#[test]
fn table_covers_every_target_kind_exactly_once() {
    assert_eq!(TABLE.len(), TargetKind::ALL.len(), "row count != enum size");
    for kind in TargetKind::ALL {
        let rows: Vec<_> = TABLE.iter().filter(|r| r.kind == kind).collect();
        assert_eq!(rows.len(), 1, "{kind:?} must have exactly one conformance row");
    }
}

/// Each row's static expectations hold: the factory yields the declared
/// family, launch-config presence matches, and `is_gpu` agrees with the
/// family tag (the two must never drift apart — sharding and serving
/// branch on both).
#[test]
fn families_and_launch_expectations_match() {
    for row in &TABLE {
        let lw = codegen::lowering_for(row.kind);
        assert_eq!(lw.family(), row.family, "{:?}", row.kind);
        assert_eq!(row.kind.is_gpu(), row.family == "gpu", "{:?}", row.kind);
        assert_eq!(row.expects_launch, row.family == "gpu", "{:?}", row.kind);
        assert!(!lw.describe().is_empty(), "{:?} has no march description", row.kind);
    }
}

/// Schedule totality and work preservation: every op in the matrix has a
/// non-empty space on every backend, every sampled config builds, and the
/// built IR carries the op's flops (exactly where the family promises
/// exactness, at least otherwise — schedules reorder work, never change
/// it).
#[test]
fn spaces_schedules_and_flops_conform() {
    for row in &TABLE {
        let lw = codegen::lowering_for(row.kind);
        for op in op_matrix() {
            let space = lw.space(&op);
            assert!(space.size() > 0, "{op} on {:?}: empty space", row.kind);
            for cfg in sample_cfgs(lw.as_ref(), &op, 4) {
                let f = lw.schedule(&op, &cfg);
                if row.promises_exact_flops {
                    assert_eq!(
                        f.total_flops(),
                        op.flops(),
                        "{op} on {:?} cfg {cfg:?}",
                        row.kind
                    );
                } else {
                    assert!(
                        f.total_flops() > 0,
                        "{op} on {:?} cfg {cfg:?}: no work",
                        row.kind
                    );
                }
            }
        }
    }
}

/// Lowering well-formedness: no panics, non-empty programs, launch
/// metadata present exactly when the row expects it.
#[test]
fn lowering_emits_wellformed_programs() {
    for row in &TABLE {
        let lw = codegen::lowering_for(row.kind);
        for op in op_matrix() {
            for cfg in sample_cfgs(lw.as_ref(), &op, 3) {
                let f = lw.schedule(&op, &cfg);
                let prog = lw.lower(&f);
                assert!(prog.total_instrs() > 0, "{op} on {:?}: empty program", row.kind);
                assert_eq!(
                    prog.launch.is_some(),
                    row.expects_launch,
                    "{op} on {:?}: launch presence",
                    row.kind
                );
            }
        }
    }
}

/// Feature conformance: extraction succeeds on every sampled lowering,
/// every value is finite, and the dimension equals the backend's declared
/// feature-name count for every op×config (coefficients index into the
/// names, so a single ragged vector breaks scoring).
#[test]
fn features_are_finite_and_dimension_stable() {
    for row in &TABLE {
        let lw = codegen::lowering_for(row.kind);
        let dim = lw.feature_names().len();
        assert!(dim > 0, "{:?}: no features", row.kind);
        assert_eq!(lw.default_coeffs().len(), dim, "{:?}: coeffs/names ragged", row.kind);
        for op in op_matrix() {
            for cfg in sample_cfgs(lw.as_ref(), &op, 3) {
                let f = lw.schedule(&op, &cfg);
                let prog = lw.lower(&f);
                let fv = lw
                    .extract(&f, &prog)
                    .unwrap_or_else(|e| panic!("{op} on {:?}: extract failed {e}", row.kind));
                assert_eq!(fv.dim(), dim, "{op} on {:?} cfg {cfg:?}", row.kind);
                for (i, v) in fv.values.iter().enumerate() {
                    assert!(
                        v.is_finite() && *v >= 0.0,
                        "{op} on {:?}: feature {} = {v}",
                        row.kind,
                        lw.feature_names()[i]
                    );
                }
            }
        }
    }
}

/// Simulation conformance: the backend's ground-truth simulator prices
/// every sampled schedule at a strictly positive latency.
#[test]
fn simulation_prices_every_backend() {
    let op = OpSpec::Matmul { m: 48, n: 48, k: 32, epilogue: Epilogue::Bias };
    for row in &TABLE {
        let lw = codegen::lowering_for(row.kind);
        for cfg in sample_cfgs(lw.as_ref(), &op, 3) {
            let f = lw.schedule(&op, &cfg);
            let prog = lw.lower(&f);
            let r = lw.simulate(&f, &prog);
            assert!(r.seconds > 0.0, "{op} on {:?} cfg {cfg:?}", row.kind);
        }
    }
}

/// Tune → cache → save → load → save round trip, per backend: the tuned
/// entry lands under this target's key prefix, and the persisted bytes
/// are a fixed point of load→save (bit-identical re-serialization is what
/// lets shard merges and fleet journals compare caches by bytes).
#[test]
fn tune_cache_roundtrip_is_bit_identical_per_target() {
    let op = OpSpec::Matmul { m: 48, n: 48, k: 24, epilogue: Epilogue::None };
    let strategy = Strategy::TunaStatic(tiny_es());
    let sig = strategy.cache_sig().unwrap();
    let mut keys = Vec::new();
    for row in &TABLE {
        let c = Coordinator::new_uncalibrated(row.kind);
        let rep = c.tune_op(&op, &strategy);
        assert!(!rep.top_k.is_empty(), "{:?}: no top-k", row.kind);

        let space = transform::config_space(&op, row.kind);
        let key = ScheduleCache::key(row.kind, &op, &space, &sig);
        assert!(
            key.starts_with(&format!("{:?}/", row.kind)),
            "{key} lost its target prefix"
        );
        keys.push(key.clone());

        let exported = c.export_cache();
        assert!(exported.peek(&key).is_some(), "{:?}: tuned entry not cached", row.kind);

        let path = std::env::temp_dir().join(format!(
            "tuna_conformance_{}_{}.json",
            row.kind.wire_name(),
            std::process::id()
        ));
        exported.save(&path).unwrap();
        let first = std::fs::read_to_string(&path).unwrap();
        let back = ScheduleCache::load(&path).unwrap();
        assert_eq!(
            back.peek(&key).map(|e| e.chosen.clone()),
            exported.peek(&key).map(|e| e.chosen.clone()),
            "{:?}: chosen config did not survive the file",
            row.kind
        );
        back.save(&path).unwrap();
        let second = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(first, second, "{:?}: save→load→save not bit-identical", row.kind);
    }
    // the same op tuned on every backend lands under distinct addresses
    let mut dedup = keys.clone();
    dedup.sort();
    dedup.dedup();
    assert_eq!(dedup.len(), keys.len(), "cache keys collided across targets: {keys:?}");
}
