//! Property-based tests over the core invariants (hand-rolled generators
//! on the deterministic in-tree RNG — the offline environment has no
//! proptest; same idea: random cases + shrink-free minimal assertions).

use std::collections::BTreeMap;
use tuna::analysis::{AnyScorer, CostError, LinearScorer, QuadraticScorer};
use tuna::eval::{CacheJournal, CachedSchedule};
use tuna::isa::TargetKind;
use tuna::isets::{Affine, StridedSet};
use tuna::serve::protocol::{ErrorCode, OpOutcome, Request, Response, TargetStats, TuneParams};
use tuna::tir::ops::{Epilogue, OpSpec};
use tuna::transform;
use tuna::transform::ScheduleConfig;
use tuna::util::Rng;

const CASES: usize = 60;

fn random_epilogue(rng: &mut Rng) -> Epilogue {
    Epilogue::ALL[rng.below(Epilogue::ALL.len())]
}

fn random_op(rng: &mut Rng) -> OpSpec {
    let pick = |rng: &mut Rng, xs: &[i64]| xs[rng.below(xs.len())];
    match rng.below(5) {
        0 => OpSpec::Matmul {
            m: pick(rng, &[16, 32, 48, 64]),
            n: pick(rng, &[16, 32, 64]),
            k: pick(rng, &[16, 24, 64]),
            epilogue: random_epilogue(rng),
        },
        1 => OpSpec::BatchMatmul {
            b: pick(rng, &[2, 4]),
            m: pick(rng, &[16, 32]),
            n: pick(rng, &[16, 32]),
            k: pick(rng, &[16, 32]),
        },
        2 => OpSpec::Conv2d {
            n: 1,
            cin: pick(rng, &[4, 8, 16]),
            h: pick(rng, &[8, 14]),
            w: pick(rng, &[8, 14]),
            cout: pick(rng, &[8, 16]),
            kh: 3,
            kw: 3,
            stride: pick(rng, &[1, 2]),
            pad: 1,
            epilogue: random_epilogue(rng),
        },
        3 => OpSpec::DepthwiseConv2d {
            n: 1,
            c: pick(rng, &[8, 16, 32]),
            h: pick(rng, &[8, 14]),
            w: pick(rng, &[8, 14]),
            kh: 3,
            kw: 3,
            stride: pick(rng, &[1, 2]),
            pad: 1,
            epilogue: random_epilogue(rng),
        },
        _ => OpSpec::Conv2dWinograd {
            n: 1,
            cin: pick(rng, &[4, 8]),
            h: pick(rng, &[8, 12]),
            w: pick(rng, &[8, 12]),
            cout: pick(rng, &[8, 16]),
        },
    }
}

/// INVARIANT: every schedule in every space computes the same flops —
/// transformations never change the work, only its order.
#[test]
fn prop_schedules_preserve_flops() {
    let mut rng = Rng::new(101);
    for case in 0..CASES {
        let op = random_op(&mut rng);
        let target = [TargetKind::Graviton2, TargetKind::TeslaV100, TargetKind::SiFiveU74]
            [case % 3];
        let space = transform::config_space(&op, target);
        let cfg = space.random(&mut rng);
        let f = transform::apply(&op, target, &cfg);
        if target.is_gpu() {
            // GPU templates include copy stages; compare MulAdd instances
            let muladds: u64 = f
                .statements()
                .iter()
                .filter(|(_, s)| s.op == tuna::tir::StmtOp::MulAdd)
                .map(|(st, s)| {
                    st.iter().map(|l| l.extent as u64).product::<u64>() * s.op.flops()
                })
                .sum();
            // winograd-on-GPU is GEMM-stage only (documented substitution);
            // MulAdds cover the contraction — a fused tail contributes
            // Add/Max statements, priced separately in op.flops()
            if !matches!(op, OpSpec::Conv2dWinograd { .. }) {
                assert_eq!(muladds, op.unfused().flops(), "case {case}: {op} cfg {cfg:?}");
            } else {
                assert!(muladds > 0);
            }
        } else {
            assert_eq!(f.total_flops(), op.flops(), "case {case}: {op} cfg {cfg:?}");
        }
    }
}

/// INVARIANT: Algorithm 1's recovered instruction executions equal the
/// IR-side statement instances for arbitrary CPU schedules.
#[test]
fn prop_loop_map_recovers_exact_counts() {
    use tuna::analysis::loop_map;
    use tuna::isa::march::xeon_8124m;
    use tuna::isa::Opcode;
    let march = xeon_8124m();
    let lanes = 16u64;
    let mut rng = Rng::new(202);
    for case in 0..CASES {
        let op = random_op(&mut rng);
        let target = TargetKind::XeonPlatinum8124M;
        let space = transform::config_space(&op, target);
        let cfg = space.random(&mut rng);
        let f = transform::apply(&op, target, &cfg);
        let prog = tuna::codegen::cpu::CpuCodegen::new(&march).lower(&f);
        let lm = loop_map::map_loops(&f, &prog);
        let vec_lanes: u64 = {
            let mut s = 0;
            for (i, b) in prog.blocks.iter().enumerate() {
                for ins in &b.instrs {
                    if ins.op == Opcode::VFma {
                        s += lm.block_trips[i] * lanes;
                    }
                }
            }
            s
        };
        let scalar = lm.count_instrs(&prog, |i| i.op == Opcode::SFma);
        let muladds: u64 = f
            .statements()
            .iter()
            .filter(|(_, s)| s.op == tuna::tir::StmtOp::MulAdd)
            .map(|(st, _)| st.iter().map(|l| l.extent as u64).product::<u64>())
            .sum();
        assert_eq!(vec_lanes + scalar, muladds, "case {case}: {op} cfg {cfg:?}");
    }
}

/// INVARIANT: the space index mapping is a bijection.
#[test]
fn prop_space_index_bijection() {
    let mut rng = Rng::new(303);
    for _ in 0..CASES {
        let op = random_op(&mut rng);
        let target = TargetKind::Graviton2;
        let space = transform::config_space(&op, target);
        for _ in 0..10 {
            let idx = (rng.next_u64()) % space.size();
            let cfg = space.from_index(idx);
            assert!(space.contains(&cfg));
            assert_eq!(space.to_index(&cfg), idx);
        }
    }
}

/// INVARIANT: cache-model movement is monotone non-increasing in cache
/// size, bounded below by footprint and above by total accesses.
#[test]
fn prop_cache_model_monotone_and_bounded() {
    use tuna::analysis::cache;
    let mut rng = Rng::new(404);
    for case in 0..30 {
        let op = random_op(&mut rng);
        let target = TargetKind::Graviton2;
        let space = transform::config_space(&op, target);
        let cfg = space.random(&mut rng);
        let f = transform::apply(&op, target, &cfg);
        if target.is_gpu() {
            continue;
        }
        let small = cache::analyze(&f, 512);
        let mid = cache::analyze(&f, 16 * 1024);
        let big = cache::analyze(&f, 64 * 1024 * 1024);
        assert!(
            small.dmov_elems + 1e-6 >= mid.dmov_elems,
            "case {case} {op}: small {} < mid {}",
            small.dmov_elems,
            mid.dmov_elems
        );
        assert!(mid.dmov_elems + 1e-6 >= big.dmov_elems, "case {case} {op}");
        // with an infinite cache movement equals footprint
        assert!(
            (big.dmov_elems - big.footprint_elems as f64).abs() <= 1e-6,
            "case {case} {op}: dmov {} fp {}",
            big.dmov_elems,
            big.footprint_elems
        );
        // never below footprint
        assert!(small.dmov_elems + 1e-6 >= small.footprint_elems as f64, "case {case} {op}");
    }
}

/// INVARIANT: affine substitution then evaluation == evaluation with the
/// substituted binding (subst correctness).
#[test]
fn prop_affine_subst_eval_commute() {
    let mut rng = Rng::new(505);
    for _ in 0..200 {
        // random affine over vars 0..4
        let mut e = Affine::constant(rng.below(20) as i64 - 10);
        for v in 0..4u32 {
            if rng.f64() < 0.7 {
                e = e.add(&Affine::scaled(v, rng.below(9) as i64 - 4));
            }
        }
        // random replacement for var 1: a*v2 + b
        let repl = Affine::scaled(2, rng.below(5) as i64).add_const(rng.below(7) as i64);
        let sub = e.subst(1, &repl);
        let env = |v: u32| [3i64, 0, 5, -2][v as usize]; // v1 unused after subst
        let env_orig = |v: u32| -> i64 {
            if v == 1 {
                repl.eval(&env)
            } else {
                env(v)
            }
        };
        assert_eq!(sub.eval(&env), e.eval(&env_orig));
        assert!(!sub.uses_var(1));
    }
}

/// INVARIANT: StridedSet unions never under-count and contain both sides'
/// extrema; Minkowski sums have cardinality ≤ product and ≥ max side.
#[test]
fn prop_strided_set_algebra() {
    let mut rng = Rng::new(606);
    for _ in 0..300 {
        let a = StridedSet::arithmetic(
            rng.below(40) as i64 - 20,
            rng.below(6) as i64 + 1,
            rng.below(30) as i64 + 1,
        );
        let b = StridedSet::arithmetic(
            rng.below(40) as i64 - 20,
            rng.below(6) as i64 + 1,
            rng.below(30) as i64 + 1,
        );
        let u = a.union(&b);
        assert!(u.cardinality() >= a.cardinality().max(b.cardinality()));
        assert!(u.min() == a.min().min(b.min()));
        assert!(u.max() == a.max().max(b.max()));
        assert!(u.contains(a.min()) && u.contains(b.max()));

        let m = a.minkowski(&b);
        assert!(m.cardinality() <= a.cardinality() * b.cardinality());
        assert!(m.cardinality() >= a.cardinality().max(b.cardinality()));
        assert_eq!(m.min(), a.min() + b.min());
        assert_eq!(m.max(), a.max() + b.max());
    }
}

// ---------------------------------------------------------------------
// serve-protocol properties: arbitrary Request/Response values survive
// encode → decode bit-identically, and the decoder is total (truncation,
// trailing garbage and wrong shapes are errors, never panics).

fn random_target(rng: &mut Rng) -> TargetKind {
    TargetKind::ALL[rng.below(TargetKind::ALL.len())]
}

/// Strings with every character class the escaper must survive: quotes,
/// backslashes, control characters, multi-byte UTF-8, spaces.
fn random_string(rng: &mut Rng) -> String {
    const PIECES: [&str; 8] = [
        "caches/merged.json",
        "/tmp/with space",
        "q\"uote",
        "back\\slash",
        "line\nbreak\ttab",
        "ünïcødé—カタカナ",
        "ctl\u{1}\u{1f}",
        "",
    ];
    let mut s = String::new();
    for _ in 0..rng.below(4) {
        s.push_str(PIECES[rng.below(PIECES.len())]);
    }
    s
}

fn random_params(rng: &mut Rng) -> TuneParams {
    TuneParams {
        population: 1 + rng.below(64),
        iterations: 1 + rng.below(32),
        sigma: 0.25 * (1 + rng.below(8)) as f64,
        alpha: 0.1 * (1 + rng.below(20)) as f64,
        k: 1 + rng.below(64),
        // full-range: the wire carries seeds as decimal strings, so bits
        // above 2^53 must survive too
        seed: rng.next_u64(),
    }
}

fn random_request(rng: &mut Rng) -> Request {
    match rng.below(7) {
        0 => Request::Tune {
            target: random_target(rng),
            op: random_op(rng),
            params: if rng.below(2) == 0 { None } else { Some(random_params(rng)) },
        },
        1 => Request::TuneNet {
            target: random_target(rng),
            ops: (0..1 + rng.below(6)).map(|_| random_op(rng)).collect(),
            params: if rng.below(2) == 0 { None } else { Some(random_params(rng)) },
        },
        2 => Request::Stats,
        3 => Request::Metrics,
        4 => Request::Recalibrate {
            target: random_target(rng),
            coeffs: (0..rng.below(9)).map(|_| rng.f64() * 4.0 - 2.0).collect(),
        },
        5 => Request::Save { path: random_string(rng) },
        _ => Request::Shutdown,
    }
}

fn random_stats(rng: &mut Rng) -> TargetStats {
    TargetStats {
        entries: rng.below(10_000) as u64,
        hits: rng.below(10_000) as u64,
        misses: rng.below(10_000) as u64,
        evictions: rng.below(100) as u64,
        searches: rng.below(10_000) as u64,
        feature_hits: rng.below(1_000_000) as u64,
        feature_misses: rng.below(1_000_000) as u64,
    }
}

fn random_outcome(rng: &mut Rng) -> OpOutcome {
    if rng.below(4) == 0 {
        OpOutcome::Failed {
            op: random_op(rng),
            code: ErrorCode::ALL[rng.below(ErrorCode::ALL.len())],
            detail: random_string(rng),
        }
    } else {
        OpOutcome::Tuned {
            op: random_op(rng),
            config: ScheduleConfig {
                choices: (0..rng.below(7)).map(|_| rng.below(16)).collect(),
            },
            predicted_cost: rng.f64() * 1e6,
            latency_s: rng.f64(),
            cache_hit: rng.below(2) == 0,
            evaluations: rng.below(1_000_000) as u64,
        }
    }
}

fn random_response(rng: &mut Rng) -> Response {
    match rng.below(8) {
        0 => Response::Tuned {
            target: random_target(rng),
            op: random_op(rng),
            config: ScheduleConfig {
                choices: (0..rng.below(7)).map(|_| rng.below(16)).collect(),
            },
            predicted_cost: rng.f64() * 1e6,
            latency_s: rng.f64(),
            cache_hit: rng.below(2) == 0,
            evaluations: rng.below(1_000_000) as u64,
        },
        6 => Response::TunedNet {
            target: random_target(rng),
            results: (0..rng.below(5)).map(|_| random_outcome(rng)).collect(),
        },
        // multi-line Prometheus text with label quotes and backslashes —
        // worst case for the line-oriented escaper
        7 => Response::Metrics {
            text: format!(
                "# HELP x y\n# TYPE x counter\nx{{t=\"{}\"}} {}\n",
                random_string(rng),
                rng.below(1_000_000)
            ),
        },
        1 => {
            let mut targets = BTreeMap::new();
            for _ in 0..rng.below(4) {
                targets.insert(random_target(rng).wire_name().to_string(), random_stats(rng));
            }
            Response::Stats { targets }
        }
        2 => Response::Recalibrated {
            target: random_target(rng),
            reranked: rng.below(1000) as u64,
        },
        3 => Response::Saved { path: random_string(rng), entries: rng.below(1000) as u64 },
        4 => Response::ShuttingDown,
        _ => Response::Error {
            code: ErrorCode::ALL[rng.below(ErrorCode::ALL.len())],
            detail: random_string(rng),
        },
    }
}

/// INVARIANT: every request survives the wire bit-identically.
#[test]
fn prop_protocol_requests_roundtrip() {
    let mut rng = Rng::new(808);
    for case in 0..250 {
        let req = random_request(&mut rng);
        let line = req.encode();
        let back = Request::decode(&line)
            .unwrap_or_else(|e| panic!("case {case}: rejected own encoding {line}: {e}"));
        assert_eq!(back, req, "case {case}: {line}");
    }
}

/// INVARIANT: every response — including every error variant — survives
/// the wire bit-identically.
#[test]
fn prop_protocol_responses_roundtrip() {
    // systematically: each error code, with an adversarial detail string
    let mut rng = Rng::new(909);
    for code in ErrorCode::ALL {
        let r = Response::Error { code, detail: random_string(&mut rng) };
        let line = r.encode();
        assert_eq!(Response::decode(&line).unwrap(), r, "{line}");
    }
    for case in 0..250 {
        let resp = random_response(&mut rng);
        let line = resp.encode();
        let back = Response::decode(&line)
            .unwrap_or_else(|e| panic!("case {case}: rejected own encoding {line}: {e}"));
        assert_eq!(back, resp, "case {case}: {line}");
    }
}

/// INVARIANT: the decoders are total — every strict prefix of a valid
/// line and every trailing-garbage extension is a typed error, and none
/// of them panic. (A network peer controls these bytes.)
#[test]
fn prop_protocol_decoder_rejects_truncation_and_trailing_garbage() {
    let mut rng = Rng::new(1010);
    for _ in 0..40 {
        let line = random_request(&mut rng).encode();
        for cut in 0..line.len() {
            if !line.is_char_boundary(cut) {
                continue;
            }
            assert!(
                Request::decode(&line[..cut]).is_err(),
                "prefix {cut} of {line} accepted"
            );
        }
        for garbage in ["x", " {}", r#"{"cmd":"stats"}"#] {
            assert!(
                Request::decode(&format!("{line}{garbage}")).is_err(),
                "trailing {garbage:?} after {line} accepted"
            );
        }

        let resp = random_response(&mut rng).encode();
        for cut in 0..resp.len() {
            if !resp.is_char_boundary(cut) {
                continue;
            }
            assert!(
                Response::decode(&resp[..cut]).is_err(),
                "prefix {cut} of {resp} accepted"
            );
        }
        assert!(Response::decode(&format!("{resp} null")).is_err());
    }
    // wrong-typed fields are rejected, not coerced
    for bad in [
        r#"{"cmd":3}"#,
        r#"{"cmd":"tune","target":3,"op":{"kind":"dense","m":1,"n":1,"k":1}}"#,
        r#"{"cmd":"tune","target":"graviton2","op":"dense"}"#,
        r#"{"cmd":"save","path":7}"#,
        r#"{"cmd":"recalibrate","target":"graviton2","coeffs":"all"}"#,
        "null",
        "[]",
        "42",
    ] {
        assert!(Request::decode(bad).is_err(), "accepted {bad}");
    }
}

/// INVARIANT: simulator latency respects the roofline for every schedule
/// (no schedule can beat peak flops) and is strictly positive.
#[test]
fn prop_simulator_respects_roofline() {
    use tuna::sim::Device;
    let mut rng = Rng::new(707);
    for kind in [TargetKind::Graviton2, TargetKind::TeslaV100, TargetKind::SiFiveU74] {
        let device = Device::new(kind);
        let peak = kind.build().peak_gflops();
        for _ in 0..12 {
            let op = random_op(&mut rng);
            let space = transform::config_space(&op, kind);
            let cfg = space.random(&mut rng);
            let r = device.run(&op, &cfg);
            assert!(r.seconds > 0.0);
            let achieved = op.flops() as f64 / r.seconds / 1e9;
            assert!(
                achieved <= peak * 1.001,
                "{op} on {kind:?}: {achieved} GF/s beats peak {peak}"
            );
        }
    }
}

// ---------------------------------------------------------------------
// epilogue serialization properties: every fused variant survives JSON
// bit-identically, `None` is encoded by omission, and cache files written
// before epilogues existed keep loading (and keep their keys).

/// INVARIANT: every op kind × every epilogue variant round-trips
/// `to_json` → `from_json` bit-identically; `Epilogue::None` serializes
/// by omission (so pre-fusion records never change shape); every variant
/// of a shape gets a distinct cache key.
#[test]
fn prop_epilogue_json_roundtrip_and_key_distinctness() {
    let mut rng = Rng::new(1111);
    for case in 0..CASES {
        let base = random_op(&mut rng).unfused();
        let mut keys = Vec::new();
        for e in Epilogue::ALL {
            // batch_matmul / winograd cannot fuse a tail — with_epilogue
            // declines, and that totality is part of the invariant
            let Some(op) = base.with_epilogue(e) else {
                assert!(e != Epilogue::None, "with_epilogue(None) must be total");
                continue;
            };
            let text = op.to_json().to_string();
            let back = OpSpec::from_json(&op.to_json())
                .unwrap_or_else(|err| panic!("case {case}: rejected {text}: {err}"));
            assert_eq!(back, op, "case {case}: {text}");
            if e == Epilogue::None {
                assert!(!text.contains("epilogue"), "None must be omitted: {text}");
            } else {
                assert!(text.contains(e.wire_name()), "case {case}: {text}");
            }
            keys.push(op.cache_key());
        }
        let mut dedup = keys.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), keys.len(), "case {case}: colliding keys {keys:?}");
    }
}

/// INVARIANT: version-2 cache files written before epilogues existed
/// (ops with no "epilogue" field) still load — no `UnsupportedVersion`,
/// the embedded op defaults to `Epilogue::None`, and re-saving keeps the
/// record byte-compatible (no "epilogue" key fabricated).
#[test]
fn prop_pre_epilogue_v2_cache_files_still_load() {
    use tuna::eval::ScheduleCache;
    use tuna::util::json::Json;
    let text = r#"{"version":2,"entries":{"Graviton2/dense_m32_n32_k32/s1/f9":{"chosen":[3,0,1],"best_score":1.5,"evaluations":7,"top_k":[[[3,0,1],1.5]],"op":{"kind":"dense","m":32,"n":32,"k":32}}}}"#;
    let cache = ScheduleCache::from_json(&Json::parse(text).unwrap())
        .unwrap_or_else(|e| panic!("pre-epilogue v2 file rejected: {e:?}"));
    assert_eq!(cache.len(), 1);
    let entry = cache.peek("Graviton2/dense_m32_n32_k32/s1/f9").unwrap();
    let expected = OpSpec::Matmul { m: 32, n: 32, k: 32, epilogue: Epilogue::None };
    assert_eq!(entry.op, Some(expected), "missing epilogue field must default to None");
    assert_eq!(cache.tasks().len(), 1, "pre-epilogue entries stay re-rankable");
    let resaved = cache.to_json().to_string();
    assert!(!resaved.contains("epilogue"), "re-save fabricated an epilogue: {resaved}");
    // a fused op in the same file shape parses to the fused spec
    let fused = r#"{"kind":"dense","m":32,"n":32,"k":32,"epilogue":"bias_relu"}"#;
    let op = OpSpec::from_json(&Json::parse(fused).unwrap()).unwrap();
    assert_eq!(op, expected.with_epilogue(Epilogue::BiasRelu).unwrap());
}

// ---------------------------------------------------------------------
// journal recovery properties: arbitrary truncation and corruption of a
// `.tunaj` file recovers exactly the complete, checksum-valid records —
// never a panic, never a garbage entry (format: docs/CACHE_FORMAT.md).

fn random_entry(rng: &mut Rng) -> CachedSchedule {
    fn cfg(rng: &mut Rng) -> ScheduleConfig {
        ScheduleConfig { choices: (0..1 + rng.below(4)).map(|_| rng.below(8)).collect() }
    }
    let mut top_k: Vec<(ScheduleConfig, f64)> = (0..1 + rng.below(3))
        .map(|_| (cfg(rng), rng.below(100_000) as f64 * 0.001))
        .collect();
    top_k.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    CachedSchedule {
        chosen: top_k[0].0.clone(),
        best_score: top_k[0].1,
        top_k,
        evaluations: rng.below(500) as u64,
        // a quarter of entries look like v1 migrations (no embedded op)
        op: if rng.below(4) == 0 { None } else { Some(random_op(rng)) },
    }
}

fn journal_temp(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("tuna_prop_{tag}_{}.tunaj", std::process::id()))
}

/// INVARIANT: replay returns every appended record in order (duplicates
/// included), and `into_cache` folds them with last-wins.
#[test]
fn prop_journal_replay_matches_appends_with_last_wins() {
    let mut rng = Rng::new(4242);
    let path = journal_temp("roundtrip");
    for case in 0..12 {
        let keys = ["k/a", "k/b", "k/c"];
        let mut j = CacheJournal::create(&path).unwrap();
        let mut appended: Vec<(String, CachedSchedule)> = Vec::new();
        for _ in 0..1 + rng.below(6) {
            let key = keys[rng.below(keys.len())].to_string();
            let e = random_entry(&mut rng);
            j.append(&key, &e).unwrap();
            appended.push((key, e));
        }
        drop(j);
        let replay = CacheJournal::replay(&path).unwrap();
        assert_eq!(replay.dropped, 0, "case {case}");
        assert_eq!(replay.entries, appended, "case {case}");

        let mut want = BTreeMap::new();
        for (k, e) in appended {
            want.insert(k, e);
        }
        let cache = CacheJournal::replay(&path).unwrap().into_cache();
        assert_eq!(cache.len(), want.len(), "case {case}");
        for (k, e) in &want {
            assert_eq!(cache.peek(k), Some(e), "case {case}: {k} did not last-win");
        }
    }
    let _ = std::fs::remove_file(&path);
}

/// INVARIANT: for EVERY byte-length prefix of a journal (every possible
/// torn write), replay recovers exactly the records whose bytes survived
/// (a record missing only its trailing newline counts as survived) — and
/// `open` repairs the tail so a subsequent replay sees zero drops.
#[test]
fn prop_journal_every_prefix_truncation_recovers_complete_records() {
    let mut rng = Rng::new(8484);
    let full = journal_temp("trunc_full");
    let cut_path = journal_temp("trunc_cut");
    for case in 0..10 {
        let mut j = CacheJournal::create(&full).unwrap();
        let mut appended: Vec<(String, CachedSchedule)> = Vec::new();
        let mut ends: Vec<usize> = Vec::new();
        for i in 0..1 + rng.below(4) {
            let e = random_entry(&mut rng);
            j.append(&format!("k/{i}"), &e).unwrap();
            appended.push((format!("k/{i}"), e));
            ends.push(std::fs::metadata(&full).unwrap().len() as usize);
        }
        drop(j);
        let bytes = std::fs::read(&full).unwrap();
        for cut in 0..=bytes.len() {
            std::fs::write(&cut_path, &bytes[..cut]).unwrap();
            let replay = CacheJournal::replay(&cut_path)
                .unwrap_or_else(|e| panic!("case {case} cut {cut}: typed error {e}"));
            // a record survives iff at most its newline is missing
            let want = ends.iter().filter(|&&end| cut + 1 >= end).count();
            assert_eq!(replay.records(), want, "case {case} cut {cut}");
            assert_eq!(replay.entries, appended[..want], "case {case} cut {cut}");
            assert!(replay.dropped <= 1, "case {case} cut {cut}: {}", replay.dropped);

            // open() must repair the tail in place: same recovery, and the
            // file it leaves behind replays clean
            let (j, repaired) = CacheJournal::open(&cut_path)
                .unwrap_or_else(|e| panic!("case {case} cut {cut}: open failed {e}"));
            assert_eq!(repaired.records(), want, "case {case} cut {cut}: open diverged");
            drop(j);
            let clean = CacheJournal::replay(&cut_path).unwrap();
            assert_eq!(clean.records(), want, "case {case} cut {cut}: repair lost records");
            assert_eq!(clean.dropped, 0, "case {case} cut {cut}: torn tail left behind");
        }
    }
    let _ = std::fs::remove_file(&full);
    let _ = std::fs::remove_file(&cut_path);
}

/// INVARIANT: a single bit flip anywhere past the header drops the
/// affected record(s) — the struck record, plus its successor if the flip
/// destroyed the newline between them — and nothing else. The corruption
/// is always *noticed* (dropped > 0) and never replayed as data.
#[test]
fn prop_journal_bit_flips_never_load_garbage() {
    let mut rng = Rng::new(2626);
    let full = journal_temp("flip_full");
    let flip_path = journal_temp("flip");
    for case in 0..CASES {
        let mut j = CacheJournal::create(&full).unwrap();
        let header_len = std::fs::metadata(&full).unwrap().len() as usize;
        let mut appended: Vec<(String, CachedSchedule)> = Vec::new();
        let mut bounds: Vec<(usize, usize)> = Vec::new();
        let mut prev = header_len;
        for i in 0..2 + rng.below(3) {
            let e = random_entry(&mut rng);
            j.append(&format!("k/{i}"), &e).unwrap();
            appended.push((format!("k/{i}"), e));
            let end = std::fs::metadata(&full).unwrap().len() as usize;
            bounds.push((prev, end));
            prev = end;
        }
        drop(j);
        let mut bytes = std::fs::read(&full).unwrap();
        let idx = header_len + rng.below(bytes.len() - header_len);
        bytes[idx] ^= 1 << rng.below(8);
        std::fs::write(&flip_path, &bytes).unwrap();

        let victim = bounds.iter().position(|&(s, e)| s <= idx && idx < e).unwrap();
        // flipping the record's own newline fuses it with its successor:
        // one unparseable line, two records lost
        let ate_newline = idx == bounds[victim].1 - 1;
        let want: Vec<(String, CachedSchedule)> = appended
            .iter()
            .enumerate()
            .filter(|(i, _)| *i != victim && !(ate_newline && *i == victim + 1))
            .map(|(_, rec)| rec.clone())
            .collect();

        let replay = CacheJournal::replay(&flip_path)
            .unwrap_or_else(|e| panic!("case {case} idx {idx}: typed error {e}"));
        assert_eq!(replay.entries, want, "case {case}: flip at {idx}");
        assert!(replay.dropped >= 1, "case {case}: flip at {idx} went unnoticed");
    }
    let _ = std::fs::remove_file(&full);
    let _ = std::fs::remove_file(&flip_path);
}

// ---------------------------------------------------------------------
// backend-extensibility properties: the target enum, its wire names and
// the cache address space must stay collision-free as backends are added
// (these pinned the RISC-V backend's arrival; the next backend rides the
// same assertions for free).

/// INVARIANT: target wire names round-trip for every enum variant, are
/// mutually distinct, and unknown/non-canonical names are rejected — the
/// serve protocol's target field depends on this staying total.
#[test]
fn prop_target_wire_names_roundtrip_over_all() {
    let mut wires = Vec::new();
    for kind in TargetKind::ALL {
        let wire = kind.wire_name();
        assert_eq!(TargetKind::from_wire(wire), Some(kind), "{kind:?}");
        wires.push(wire);
    }
    let mut dedup = wires.clone();
    dedup.sort();
    dedup.dedup();
    assert_eq!(dedup.len(), TargetKind::ALL.len(), "colliding wire names {wires:?}");
    // strict inverse: aliases and case variants belong to the CLI parser,
    // never to the wire
    for bad in ["tpu", "", "XEON", "riscv", "rv64", "unmatched", "u-74"] {
        assert!(TargetKind::from_wire(bad).is_none(), "{bad:?} accepted on the wire");
    }
}

/// INVARIANT: cache keys are distinct across every target × base op ×
/// epilogue combination — a new backend can never alias another target's
/// entries even when it shares a config-space fingerprint (the RISC-V
/// spaces are bit-identical to the CPU ones; only the kind prefix
/// separates them).
#[test]
fn prop_cache_keys_distinct_across_targets_ops_epilogues() {
    use std::collections::BTreeSet;
    use tuna::eval::ScheduleCache;
    // dedup the figure suite down to unique unfused shapes first: two
    // suite entries sharing a base shape *should* share fused keys
    let mut bases = Vec::new();
    let mut seen = BTreeSet::new();
    for op in tuna::tir::ops::figure_op_suite() {
        let base = op.unfused();
        if seen.insert(base.cache_key()) {
            bases.push(base);
        }
    }
    let mut keys = BTreeSet::new();
    let mut count = 0usize;
    for kind in TargetKind::ALL {
        for base in &bases {
            for e in Epilogue::ALL {
                let Some(op) = base.with_epilogue(e) else { continue };
                let space = transform::config_space(&op, kind);
                let key = ScheduleCache::key(kind, &op, &space, "es_p8_i4");
                assert!(
                    key.starts_with(&format!("{kind:?}/")),
                    "{key} lost its target prefix"
                );
                assert!(keys.insert(key.clone()), "duplicate cache key {key}");
                count += 1;
            }
        }
    }
    assert_eq!(keys.len(), count);
}

/// INVARIANT: a version-2 cache file written before the RISC-V backend
/// existed still loads with the enum's sixth variant present, entries for
/// the new target coexist in the same file, per-target filtering slices
/// cleanly, and re-saving is byte-stable (save → load → save is the
/// identity on bytes).
#[test]
fn prop_v2_cache_files_byte_stable_with_new_target() {
    use tuna::eval::ScheduleCache;
    use tuna::util::json::Json;
    let text = concat!(
        r#"{"version":2,"entries":{"#,
        r#""Graviton2/dense_m32_n32_k32/000000000000002a/es_p8_i4":"#,
        r#"{"chosen":[3,0,1],"best_score":1.5,"evaluations":7,"top_k":[[[3,0,1],1.5]],"op":{"kind":"dense","m":32,"n":32,"k":32}},"#,
        r#""SiFiveU74/dense_m32_n32_k32/000000000000002a/es_p8_i4":"#,
        r#"{"chosen":[1,2,0],"best_score":9.5,"evaluations":5,"top_k":[[[1,2,0],9.5]],"op":{"kind":"dense","m":32,"n":32,"k":32}},"#,
        r#""TeslaV100/dense_m32_n32_k32/00000000000000ff/es_p8_i4":"#,
        r#"{"chosen":[2],"best_score":0.5,"evaluations":9,"top_k":[[[2],0.5]],"op":{"kind":"dense","m":32,"n":32,"k":32}}"#,
        r#"}}"#,
    );
    let cache = ScheduleCache::from_json(&Json::parse(text).unwrap())
        .unwrap_or_else(|e| panic!("v2 file with u74 entries rejected: {e:?}"));
    assert_eq!(cache.len(), 3);
    for kind in [TargetKind::Graviton2, TargetKind::SiFiveU74, TargetKind::TeslaV100] {
        assert_eq!(cache.filter_target(kind).len(), 1, "{kind:?} slice wrong");
    }
    for kind in [TargetKind::XeonPlatinum8124M, TargetKind::CortexA53, TargetKind::JetsonXavier] {
        assert_eq!(cache.filter_target(kind).len(), 0, "{kind:?} slice not empty");
    }
    let saved = cache.to_json().to_string();
    let reloaded = ScheduleCache::from_json(&Json::parse(&saved).unwrap())
        .unwrap_or_else(|e| panic!("own save rejected: {e:?}"));
    assert_eq!(reloaded.to_json().to_string(), saved, "save→load→save not byte-stable");
}

// ---------------------------------------------------------------------
// scorer-file properties: serialized cost models survive the disk
// bit-identically for arbitrary parameters, and every malformed document
// — truncation, unknown names, ragged dimensions, wrong versions — loads
// as a typed error, never a panic and never a silently mis-sized model.

/// A random scorer with parameters spanning sign, scale and exact-zero
/// cases, dimensioned for `kind`'s feature space.
fn random_scorer(rng: &mut Rng, kind: TargetKind) -> AnyScorer {
    let dim = tuna::codegen::lowering_for(kind).feature_names().len();
    if rng.below(2) == 0 {
        let coeffs = (0..dim).map(|_| rng.f64() * 10.0).collect();
        AnyScorer::Linear(LinearScorer::new(coeffs))
    } else {
        let n = QuadraticScorer::param_len(dim);
        let mut weights: Vec<f64> = (0..n).map(|_| rng.f64() * 4.0 - 2.0).collect();
        // exact zeros exercise the integer-printing path of the writer
        for w in weights.iter_mut() {
            if rng.below(5) == 0 {
                *w = 0.0;
            }
        }
        AnyScorer::Quadratic(QuadraticScorer::from_weights(dim, weights).unwrap())
    }
}

/// INVARIANT: for arbitrary parameters on every target, a scorer survives
/// serialize → parse → serialize with byte-identical documents, and the
/// reconstructed scorer is structurally equal (fleets compare scorer
/// files by bytes to prove every worker loaded the same model).
#[test]
fn prop_scorer_files_roundtrip_byte_stable_over_random_weights() {
    use tuna::util::json::Json;
    let mut rng = Rng::new(1313);
    for case in 0..CASES {
        let kind = random_target(&mut rng);
        let scorer = random_scorer(&mut rng, kind);
        let text = scorer.to_json(kind).to_string();
        let (k2, back) = AnyScorer::from_json(&Json::parse(&text).unwrap())
            .unwrap_or_else(|e| panic!("case {case}: own encoding rejected: {e} ({text})"));
        assert_eq!(k2, kind, "case {case}: target did not round-trip");
        assert_eq!(back, scorer, "case {case}: scorer did not round-trip");
        assert_eq!(
            back.to_json(kind).to_string(),
            text,
            "case {case}: re-serialization drifted"
        );
    }
}

/// INVARIANT: every strict byte prefix of a serialized scorer file is
/// rejected as a typed [`CostError`] — a torn write or truncated copy can
/// never load as a plausible-but-wrong model.
#[test]
fn prop_scorer_file_every_prefix_truncation_rejected() {
    let mut rng = Rng::new(2727);
    let path = std::env::temp_dir()
        .join(format!("tuna_prop_scorer_trunc_{}.json", std::process::id()));
    for case in 0..6 {
        let kind = random_target(&mut rng);
        let scorer = random_scorer(&mut rng, kind);
        scorer.save(kind, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let (_, full) = AnyScorer::load(&path)
            .unwrap_or_else(|e| panic!("case {case}: complete file rejected: {e}"));
        assert_eq!(full, scorer, "case {case}");
        for cut in 0..bytes.len() {
            std::fs::write(&path, &bytes[..cut]).unwrap();
            match AnyScorer::load(&path) {
                Err(CostError::ScorerFile { .. }) => {}
                other => panic!("case {case} cut {cut}: accepted truncation: {other:?}"),
            }
        }
    }
    let _ = std::fs::remove_file(&path);
}

/// INVARIANT: structurally valid JSON with the wrong contents — unknown
/// scorer names, unknown targets, unsupported versions, dimensions that
/// disagree with the target's feature space, ragged parameter arrays —
/// is rejected with the matching typed error.
#[test]
fn prop_scorer_file_bad_documents_are_typed_errors() {
    use tuna::util::json::Json;
    let parse = |s: &str| AnyScorer::from_json(&Json::parse(s).unwrap());
    // graviton2's feature space is 7-wide; a well-formed linear document
    let good = r#"{"dim":7,"params":[1,1,1,1,1,1,1],"scorer":"linear","target":"graviton2","version":1}"#;
    assert!(parse(good).is_ok(), "reference document rejected");
    let unknown_scorer =
        r#"{"dim":7,"params":[1],"scorer":"mlp","target":"graviton2","version":1}"#;
    assert_eq!(
        parse(unknown_scorer),
        Err(CostError::UnknownScorer { name: "mlp".into() })
    );
    let unknown_target = r#"{"dim":7,"params":[1],"scorer":"linear","target":"tpu","version":1}"#;
    assert!(matches!(parse(unknown_target), Err(CostError::ScorerFile { .. })));
    let bad_version =
        r#"{"dim":7,"params":[1,1,1,1,1,1,1],"scorer":"linear","target":"graviton2","version":99}"#;
    assert!(matches!(parse(bad_version), Err(CostError::ScorerFile { .. })));
    let wrong_dim =
        r#"{"dim":6,"params":[1,1,1,1,1,1],"scorer":"linear","target":"graviton2","version":1}"#;
    assert_eq!(parse(wrong_dim), Err(CostError::CoeffDim { expected: 7, got: 6 }));
    let ragged_linear =
        r#"{"dim":7,"params":[1,1,1],"scorer":"linear","target":"graviton2","version":1}"#;
    assert_eq!(parse(ragged_linear), Err(CostError::CoeffDim { expected: 7, got: 3 }));
    let ragged_quadratic =
        r#"{"dim":7,"params":[1,1,1,1,1],"scorer":"quadratic","target":"graviton2","version":1}"#;
    assert_eq!(
        parse(ragged_quadratic),
        Err(CostError::CoeffDim { expected: QuadraticScorer::param_len(7), got: 5 })
    );
}
