//! Cross-module integration tests: the full tuning pipeline on small
//! workloads, dual-clock accounting, schedule-quality ordering, and the
//! paper-shape relations the benches quantify at scale.

use tuna::coordinator::{Coordinator, Strategy};
use tuna::graph::{Layer, Network};
use tuna::isa::TargetKind;
use tuna::search::EsParams;
use tuna::sim::Device;
use tuna::tir::ops::{Epilogue, OpSpec};

fn tiny_es() -> EsParams {
    EsParams { population: 14, iterations: 7, k: 10, seed: 9, ..Default::default() }
}

fn toy_net() -> Network {
    Network {
        name: "toy",
        display: "Toy",
        layers: vec![
            Layer::single(OpSpec::Matmul { m: 64, n: 64, k: 64, epilogue: Epilogue::None }, 2),
            Layer::single(
                OpSpec::Conv2d {
                    n: 1, cin: 8, h: 14, w: 14, cout: 16, kh: 3, kw: 3, stride: 1, pad: 1,
                    epilogue: Epilogue::None,
                },
                1,
            ),
            Layer::single(
                OpSpec::DepthwiseConv2d {
                    n: 1, c: 16, h: 14, w: 14, kh: 3, kw: 3, stride: 1, pad: 1,
                    epilogue: Epilogue::None,
                },
                3,
            ),
        ],
    }
}

/// Tuna's search result must beat the median random schedule on the device
/// — the basic "the static model is useful" claim.
#[test]
fn tuna_beats_median_random() {
    let kind = TargetKind::Graviton2;
    let c = Coordinator::new(kind);
    let op = OpSpec::Matmul { m: 128, n: 128, k: 64, epilogue: Epilogue::None };
    let r = c.tune_op(&op, &Strategy::TunaStatic(tiny_es()));
    let space = tuna::transform::config_space(&op, kind);
    let mut rng = tuna::util::Rng::new(33);
    let mut lat: Vec<f64> = (0..15)
        .map(|_| c.device.run(&op, &space.random(&mut rng)).seconds)
        .collect();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    assert!(
        r.latency_s <= lat[lat.len() / 2],
        "tuna {} vs median random {}",
        r.latency_s,
        lat[lat.len() / 2]
    );
}

/// Table-II shape: Tuna's compile time (wall only) must be far below
/// AutoTVM's (wall + sequential virtual device time), even on a toy net.
#[test]
fn compile_time_asymmetry_holds() {
    let kind = TargetKind::Graviton2;
    let c = Coordinator::new(kind);
    let net = toy_net();
    let tuna = c.tune_network(&net, &Strategy::TunaStatic(tiny_es()));
    let atvm = c.tune_network(&net, &Strategy::AutoTvmFull { trials: 16 });
    assert_eq!(tuna.device_s, 0.0, "static strategy touched the device");
    assert!(atvm.device_s > 30.0, "autotvm device time {}", atvm.device_s);
    let speedup = atvm.compile_seconds() / tuna.compile_seconds().max(1e-9);
    assert!(speedup > 3.0, "compile speedup only {speedup:.1}x");
}

/// Table-I shape: AutoTVM-Partial at Tuna's budget must not beat Tuna
/// (it can barely measure anything), while AutoTVM-Full should land in
/// Tuna's neighbourhood.
#[test]
fn equal_budget_comparison_favors_tuna() {
    let kind = TargetKind::Graviton2;
    let c = Coordinator::new(kind);
    let net = toy_net();
    let tuna = c.tune_network(&net, &Strategy::TunaStatic(tiny_es()));
    let budget = c.partial_budget_per_op(&tuna);
    let partial = c.tune_network(&net, &Strategy::AutoTvmPartial { budget_s: budget });
    assert!(
        partial.latency_s >= tuna.latency_s * 0.7,
        "partial {} unexpectedly beats tuna {} badly",
        partial.latency_s,
        tuna.latency_s
    );
}

/// The GPU pipeline works end to end too.
#[test]
fn gpu_pipeline_end_to_end() {
    let kind = TargetKind::TeslaV100;
    let c = Coordinator::new(kind);
    let op = OpSpec::Matmul { m: 256, n: 256, k: 128, epilogue: Epilogue::None };
    let r = c.tune_op(&op, &Strategy::TunaStatic(tiny_es()));
    assert!(r.latency_s > 0.0);
    assert_eq!(r.device_s, 0.0);
    // V100 should be far faster than the A53 on the same op
    let a53 = Coordinator::new(TargetKind::CortexA53);
    let r53 = a53.tune_op(&op, &Strategy::Vendor);
    assert!(r53.latency_s > r.latency_s * 3.0);
}

/// Schedule cache semantics: identical op in two layers is tuned once
/// (unique_tasks) but charged per use in the latency sum.
#[test]
fn schedule_cache_dedups_work() {
    let kind = TargetKind::Graviton2;
    let c = Coordinator::new(kind);
    let op = OpSpec::Matmul { m: 64, n: 64, k: 64, epilogue: Epilogue::None };
    let net = Network {
        name: "dup",
        display: "Dup",
        layers: vec![Layer::single(op, 1), Layer::single(op, 4)],
    };
    let rep = c.tune_network(&net, &Strategy::Vendor);
    assert_eq!(rep.per_op.len(), 1, "duplicate op tuned twice");
    let unit = rep.per_op.values().next().unwrap().latency_s;
    assert!((rep.latency_s - 5.0 * unit).abs() < 1e-12);
}

/// Alternatives: a layer carrying {direct conv, winograd} deploys the
/// faster of the two tuned families.
#[test]
fn alternative_selection_picks_faster_family() {
    let kind = TargetKind::Graviton2;
    let c = Coordinator::new(kind);
    let direct = OpSpec::Conv2d {
        n: 1, cin: 16, h: 16, w: 16, cout: 16, kh: 3, kw: 3, stride: 1, pad: 1,
        epilogue: Epilogue::None,
    };
    let wino = OpSpec::Conv2dWinograd { n: 1, cin: 16, h: 16, w: 16, cout: 16 };
    let net = Network {
        name: "alt",
        display: "Alt",
        layers: vec![Layer { alternatives: vec![direct, wino], count: 1 }],
    };
    let rep = c.tune_network(&net, &Strategy::TunaStatic(tiny_es()));
    let ld = rep.per_op[&direct.cache_key()].latency_s;
    let lw = rep.per_op[&wino.cache_key()].latency_s;
    assert!((rep.latency_s - ld.min(lw)).abs() < 1e-12);
}

/// Measurement noise is deterministic, so AutoTVM runs reproduce exactly.
#[test]
fn autotvm_is_reproducible() {
    let kind = TargetKind::Graviton2;
    let op = OpSpec::Matmul { m: 64, n: 64, k: 32, epilogue: Epilogue::None };
    let space = tuna::transform::config_space(&op, kind);
    let run = || {
        let d = Device::new(kind);
        tuna::autotvm::tune(
            &op,
            &space,
            &d,
            &tuna::autotvm::TunerParams { n_trials: 12, seed: 4, ..Default::default() },
        )
    };
    let a = run();
    let b = run();
    assert_eq!(a.result.best, b.result.best);
    assert_eq!(a.result.best_score, b.result.best_score);
    assert_eq!(a.device_seconds, b.device_seconds);
}

/// Figure-3 machinery: top-k ratio is finite, positive and ≤ ~1.2 on a
/// small operator (AutoTVM picking by measurement can't be much *worse*
/// than Tuna's static picks when both measure the same space).
#[test]
fn topk_ratio_in_plausible_band() {
    let kind = TargetKind::Graviton2;
    let c = Coordinator::new(kind);
    let op = OpSpec::Conv2d {
        n: 1, cin: 8, h: 14, w: 14, cout: 16, kh: 3, kw: 3, stride: 1, pad: 1,
        epilogue: Epilogue::None,
    };
    let ratio = tuna::metrics::topk_sweep_ratio(&c, &op, 5, 24);
    assert!(ratio.is_finite() && ratio > 0.2 && ratio < 1.5, "ratio {ratio}");
}

/// Tables render with every strategy row present.
#[test]
fn tables_render_complete() {
    use std::collections::BTreeMap;
    let kind = TargetKind::Graviton2;
    let c = Coordinator::new(kind);
    let net = toy_net();
    let mut results: BTreeMap<String, BTreeMap<String, tuna::coordinator::NetworkReport>> =
        BTreeMap::new();
    let tuna_rep = c.tune_network(&net, &Strategy::TunaStatic(tiny_es()));
    let vendor = c.tune_network(&net, &Strategy::Vendor);
    results.entry("Tuna".into()).or_default().insert("toy".into(), tuna_rep);
    results.entry("Framework".into()).or_default().insert("toy".into(), vendor);
    let t1 = tuna::metrics::table1(kind, &results, &["toy"], &["Toy"]);
    assert!(t1.contains("Tuna") && t1.contains("Framework") && t1.contains("Toy"));
    let t3 = tuna::metrics::table3(kind, &results, &["toy"], &["Toy"]);
    assert!(t3.is_some()); // graviton2 has a cloud price
    assert!(tuna::metrics::table3(TargetKind::CortexA53, &results, &["toy"], &["Toy"]).is_none());
}

/// Pin the AutoTVM baseline's surrogate — the ridge-fit log-latency model
/// whose quadratic feature-crossing technique the learned scorer grew out
/// of. It guides `autotvm::tune`'s candidate proposals, so its contract
/// matters beyond its own module: constant before any fit,
/// under-determined fits are no-ops, refits are bit-reproducible, and a
/// real fit rank-correlates with the simulator it stands in for.
#[test]
fn autotvm_surrogate_fit_predict_contract_holds() {
    use tuna::autotvm::surrogate::Surrogate;
    let kind = TargetKind::Graviton2;
    let op = OpSpec::Matmul { m: 64, n: 64, k: 32, epilogue: Epilogue::None };
    let space = tuna::transform::config_space(&op, kind);
    let device = Device::new(kind);

    // unfitted: the constant fallback, for every config
    let mut sur = Surrogate::new(&space);
    assert_eq!(sur.predict(&space.default_config()), 1.0);
    assert_eq!(sur.predict(&space.from_index(space.size() - 1)), 1.0);

    // fewer than three samples cannot determine a fit; the model must
    // stay on the fallback rather than extrapolate from noise
    let short: Vec<_> =
        (0..2).map(|i| (space.from_index(i), device.run(&op, &space.from_index(i)).seconds)).collect();
    sur.fit(&short);
    assert_eq!(sur.predict(&space.default_config()), 1.0, "under-determined fit mutated the model");

    // measure a deterministic grid on the simulator and fit for real
    let n = space.size().min(24).max(3);
    let measured: Vec<_> = (0..n)
        .map(|i| {
            let cfg = space.from_index(i * space.size() / n);
            let secs = device.run(&op, &cfg).seconds;
            (cfg, secs)
        })
        .collect();
    sur.fit(&measured);

    // the fit is deterministic: a second surrogate trained on the same
    // measurements predicts bit-identically
    let mut again = Surrogate::new(&space);
    again.fit(&measured);
    let probe = space.from_index(space.size() / 2);
    assert!(sur.predict(&probe) != 1.0, "fit did not take");
    assert_eq!(
        sur.predict(&probe).to_bits(),
        again.predict(&probe).to_bits(),
        "surrogate refit is not deterministic"
    );

    // held out: random configs the fit never saw still rank close to the
    // simulator's ground truth — the property that makes the surrogate a
    // useful search guide at all
    let mut rng = tuna::util::Rng::new(77);
    let (mut preds, mut truths) = (Vec::new(), Vec::new());
    for _ in 0..24 {
        let cfg = space.random(&mut rng);
        let p = sur.predict(&cfg);
        assert!(p.is_finite() && p > 0.0, "surrogate prediction {p} for {cfg:?}");
        preds.push(p);
        truths.push(device.run(&op, &cfg).seconds);
    }
    let rho = tuna::util::stats::spearman(&preds, &truths);
    assert!(rho > 0.3, "surrogate no longer tracks the simulator: spearman {rho}");
}
