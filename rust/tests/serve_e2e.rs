//! End-to-end tests for the tune-serving daemon: a real `Server` bound to
//! an ephemeral loopback port, driven over real TCP sockets by a
//! line-delimited JSON client — the full cache/shard/serve stack through a
//! process-boundary-shaped interface (the daemon also runs in-process
//! here so the tests can cross-check against library-level tuning).
//!
//! What must hold (the PR's acceptance criteria):
//! * the warm-cache hit path over the socket is search-free and
//!   bit-identical to in-process tuning;
//! * `recalibrate` over the socket re-ranks with zero additional lowering
//!   (feature-store miss counter frozen) and zero additional searches;
//! * `save` + a fresh daemon with warm-loaded caches serves zero-search;
//! * malformed and unknown-op requests get typed error responses on a
//!   connection that stays open — never a dropped socket or a panic.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::thread::JoinHandle;
use std::time::Duration;

use tuna::coordinator::{Coordinator, Strategy};
use tuna::isa::TargetKind;
use tuna::search::EsParams;
use tuna::serve::protocol::{ErrorCode, Request, Response, TuneParams};
use tuna::serve::{ServeConfig, Server};
use tuna::tir::ops::{Epilogue, OpSpec};

fn tiny_es() -> EsParams {
    EsParams { population: 10, iterations: 5, k: 8, seed: 23, ..Default::default() }
}

fn tiny_params() -> TuneParams {
    TuneParams::from_es(&tiny_es())
}

fn temp_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("tuna_serve_e2e_{tag}_{}.json", std::process::id()))
}

/// Bind + run a daemon on an ephemeral port; returns its address and the
/// handle that yields `run()`'s result after shutdown.
fn start_daemon(cfg: ServeConfig) -> (SocketAddr, JoinHandle<()>) {
    let server = Server::bind(cfg).expect("daemon failed to bind");
    let addr = server.local_addr();
    let handle = std::thread::spawn(move || server.run().expect("daemon run failed"));
    (addr, handle)
}

fn base_config() -> ServeConfig {
    ServeConfig {
        targets: vec![TargetKind::Graviton2],
        threads: 2,
        // latency-table coefficients: deterministic and cheap, and the
        // in-process reference coordinator below uses the same
        calibrated: false,
        ..ServeConfig::default()
    }
}

/// One line-oriented protocol client over a real socket.
struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).expect("connect failed");
        stream
            .set_read_timeout(Some(Duration::from_secs(120)))
            .expect("set_read_timeout failed");
        let writer = stream.try_clone().expect("clone failed");
        Client { reader: BufReader::new(stream), writer }
    }

    /// Send one raw line, read one response line.
    fn send_raw(&mut self, line: &str) -> Response {
        self.writer.write_all(line.as_bytes()).expect("write failed");
        self.writer.write_all(b"\n").expect("write failed");
        let mut resp = String::new();
        let n = self.reader.read_line(&mut resp).expect("read failed");
        assert!(n > 0, "server dropped the connection after {line:?}");
        Response::decode(&resp).unwrap_or_else(|e| panic!("bad response {resp:?}: {e}"))
    }

    fn send(&mut self, req: &Request) -> Response {
        self.send_raw(&req.encode())
    }

    fn tune(&mut self, target: TargetKind, op: OpSpec) -> Response {
        self.send(&Request::Tune { target, op, params: Some(tiny_params()) })
    }

    fn stats_for(&mut self, target: TargetKind) -> tuna::serve::protocol::TargetStats {
        match self.send(&Request::Stats) {
            Response::Stats { targets } => targets[target.wire_name()],
            other => panic!("stats failed: {other:?}"),
        }
    }

    fn shutdown(&mut self) {
        let resp = self.send(&Request::Shutdown);
        assert!(matches!(resp, Response::ShuttingDown), "shutdown not acked: {resp:?}");
    }
}

#[test]
fn warm_cache_hit_over_the_socket_is_search_free_and_bit_identical() {
    let (addr, daemon) = start_daemon(base_config());
    let mut client = Client::connect(addr);
    let op = OpSpec::Matmul { m: 48, n: 48, k: 24, epilogue: Epilogue::None };

    // first tune performs a search
    let first = client.tune(TargetKind::Graviton2, op);
    let Response::Tuned { cache_hit, config, predicted_cost, evaluations, latency_s, .. } =
        first.clone()
    else {
        panic!("tune failed: {first:?}");
    };
    assert!(!cache_hit, "cold daemon claimed a cache hit");
    assert!(evaluations > 0);
    assert!(latency_s > 0.0, "tune response missing deployed latency");
    assert_eq!(client.stats_for(TargetKind::Graviton2).searches, 1);

    // second identical tune: a cache hit, zero evaluations, bit-identical
    let second = client.tune(TargetKind::Graviton2, op);
    let Response::Tuned {
        cache_hit: hit2,
        config: config2,
        predicted_cost: cost2,
        evaluations: ev2,
        ..
    } = second
    else {
        panic!("second tune failed");
    };
    assert!(hit2, "repeat tune missed the schedule cache");
    assert_eq!(ev2, 0, "cache hit still evaluated candidates");
    assert_eq!(config2, config, "cache hit returned a different schedule");
    assert_eq!(cost2, predicted_cost, "cache hit re-scored the schedule");
    let stats = client.stats_for(TargetKind::Graviton2);
    assert_eq!(stats.searches, 1, "repeat tune searched again");
    assert_eq!(stats.hits, 1);

    // the daemon's choice is bit-identical to in-process tuning with the
    // same model and search parameters
    let reference = Coordinator::new_uncalibrated(TargetKind::Graviton2);
    let want = reference.tune_op(&op, &Strategy::TunaStatic(tiny_es()));
    assert_eq!(config, want.chosen, "served schedule diverged from in-process tuning");
    assert_eq!(
        predicted_cost, want.top_k[0].1,
        "served predicted cost diverged from in-process tuning"
    );

    client.shutdown();
    daemon.join().unwrap();
}

#[test]
fn recalibrate_over_the_socket_reranks_without_searching_or_lowering() {
    let (addr, daemon) = start_daemon(base_config());
    let mut client = Client::connect(addr);
    let op = OpSpec::Matmul { m: 64, n: 64, k: 64, epilogue: Epilogue::None };

    let Response::Tuned { cache_hit: false, .. } = client.tune(TargetKind::Graviton2, op)
    else {
        panic!("initial tune failed");
    };
    let before = client.stats_for(TargetKind::Graviton2);
    assert_eq!(before.searches, 1);

    // swap coefficients online: entries re-rank, nothing is re-lowered
    let coeffs = vec![0.1, 2.0, 0.5, 1.0, 0.25, 4.0, 1.5];
    let resp = client.send(&Request::Recalibrate {
        target: TargetKind::Graviton2,
        coeffs: coeffs.clone(),
    });
    let Response::Recalibrated { reranked, .. } = resp else {
        panic!("recalibrate failed: {resp:?}");
    };
    assert_eq!(reranked, 1, "resident entry was not re-ranked");
    let after = client.stats_for(TargetKind::Graviton2);
    assert_eq!(after.searches, before.searches, "recalibration triggered a search");
    assert_eq!(
        after.feature_misses, before.feature_misses,
        "recalibration re-lowered candidates (stage-1 misses moved)"
    );

    // the re-ranked entry still serves as a hit, scored exactly as a
    // fresh model with those coefficients would score it
    let served = client.tune(TargetKind::Graviton2, op);
    let Response::Tuned { cache_hit, config, predicted_cost, .. } = served else {
        panic!("post-recalibration tune failed");
    };
    assert!(cache_hit, "recalibration invalidated the cache");
    let cm = tuna::CostModel::with_coeffs(TargetKind::Graviton2, coeffs);
    assert_eq!(
        predicted_cost,
        cm.predict(&op, &config),
        "served cost is not the new model's score for the served config"
    );
    assert_eq!(client.stats_for(TargetKind::Graviton2).searches, before.searches);

    client.shutdown();
    daemon.join().unwrap();
}

#[test]
fn quadratic_daemon_rejects_recalibrate_typed_and_serves_warm_unpoisoned() {
    use tuna::analysis::ScorerSpec;
    let cfg = ServeConfig { scorer: ScorerSpec::Quadratic, ..base_config() };
    let (addr, daemon) = start_daemon(cfg);
    let mut client = Client::connect(addr);
    let op = OpSpec::Matmul { m: 48, n: 48, k: 24, epilogue: Epilogue::None };

    let first = client.tune(TargetKind::Graviton2, op);
    let Response::Tuned { cache_hit: false, config, predicted_cost, .. } = first.clone()
    else {
        panic!("cold tune under the quadratic scorer failed: {first:?}");
    };
    // the daemon's choice matches in-process tuning under the same scorer
    let reference =
        Coordinator::new_uncalibrated_with_scorer(TargetKind::Graviton2, ScorerSpec::Quadratic);
    let want = reference.tune_op(&op, &Strategy::TunaStatic(tiny_es()));
    assert_eq!(config, want.chosen, "served schedule diverged from in-process tuning");
    assert_eq!(predicted_cost, want.top_k[0].1, "served cost diverged");

    // correctly-dimensioned coefficients still cannot recalibrate a
    // nonlinear scorer: the rejection is typed and tells the operator to
    // retrain offline instead
    let resp = client.send(&Request::Recalibrate {
        target: TargetKind::Graviton2,
        coeffs: vec![1.0; 7],
    });
    let Response::Error { code, detail } = resp else {
        panic!("quadratic scorer accepted a raw coefficient swap: {resp:?}");
    };
    assert_eq!(code, ErrorCode::BadCoeffs);
    assert!(detail.contains("train-scorer"), "rejection lacks the remedy: {detail}");

    // the failed recalibrate poisoned nothing: same connection, warm hit,
    // bit-identical to the pre-failure response, no extra search
    let warm = client.tune(TargetKind::Graviton2, op);
    let Response::Tuned { cache_hit, config: wc, predicted_cost: wp, .. } = warm else {
        panic!("post-rejection tune failed");
    };
    assert!(cache_hit, "failed recalibrate invalidated the cache");
    assert_eq!(wc, config, "failed recalibrate changed the served schedule");
    assert_eq!(wp, predicted_cost, "failed recalibrate re-scored the schedule");
    assert_eq!(client.stats_for(TargetKind::Graviton2).searches, 1);

    client.shutdown();
    daemon.join().unwrap();
}

#[test]
fn save_then_fresh_daemon_with_warm_cache_serves_zero_search() {
    let path = temp_path("warm");
    let ops = [
        OpSpec::Matmul { m: 32, n: 32, k: 32, epilogue: Epilogue::None },
        OpSpec::Matmul { m: 64, n: 32, k: 32, epilogue: Epilogue::None },
    ];

    // daemon A tunes and persists
    let (addr_a, daemon_a) = start_daemon(base_config());
    let mut client = Client::connect(addr_a);
    let mut chosen = Vec::new();
    for op in ops {
        match client.tune(TargetKind::Graviton2, op) {
            Response::Tuned { config, .. } => chosen.push(config),
            other => panic!("tune failed: {other:?}"),
        }
    }
    let saved = client.send(&Request::Save { path: path.display().to_string() });
    let Response::Saved { entries, .. } = saved else { panic!("save failed: {saved:?}") };
    assert_eq!(entries, ops.len() as u64);
    client.shutdown();
    daemon_a.join().unwrap();

    // daemon B warm-loads the file and never searches
    let cfg = ServeConfig { cache_paths: vec![path.clone()], ..base_config() };
    let (addr_b, daemon_b) = start_daemon(cfg);
    let _ = std::fs::remove_file(&path);
    let mut client = Client::connect(addr_b);
    let warm = client.stats_for(TargetKind::Graviton2);
    assert_eq!(warm.entries, ops.len() as u64, "warm daemon did not load the cache");
    for (op, want) in ops.iter().zip(&chosen) {
        let served = client.tune(TargetKind::Graviton2, *op);
        let Response::Tuned { cache_hit, config, evaluations, .. } = served else {
            panic!("warm tune failed")
        };
        assert!(cache_hit, "{op} missed the warm cache");
        assert_eq!(evaluations, 0);
        assert_eq!(&config, want, "{op} served a different schedule than daemon A chose");
    }
    assert_eq!(client.stats_for(TargetKind::Graviton2).searches, 0, "warm daemon searched");

    client.shutdown();
    daemon_b.join().unwrap();
}

#[test]
fn malformed_input_gets_typed_errors_and_the_connection_survives() {
    let (addr, daemon) = start_daemon(base_config());
    let mut client = Client::connect(addr);

    let expect_error = |client: &mut Client, line: &str, code: ErrorCode| {
        match client.send_raw(line) {
            Response::Error { code: got, .. } => {
                assert_eq!(got, code, "{line:?} answered the wrong code")
            }
            other => panic!("{line:?} was accepted: {other:?}"),
        }
    };

    expect_error(&mut client, "this is not json", ErrorCode::Parse);
    expect_error(&mut client, r#"{"cmd":"stats"} trailing garbage"#, ErrorCode::Parse);
    expect_error(&mut client, "\"\\u12", ErrorCode::Parse); // truncated escape
    expect_error(&mut client, r#"{"cmd":"frobnicate"}"#, ErrorCode::BadRequest);
    expect_error(&mut client, r#"{"cmd":"tune"}"#, ErrorCode::BadRequest);
    expect_error(
        &mut client,
        r#"{"cmd":"tune","target":"tpu","op":{"kind":"dense","m":1,"n":1,"k":1}}"#,
        ErrorCode::UnknownTarget,
    );
    expect_error(
        &mut client,
        r#"{"cmd":"tune","target":"graviton2","op":{"kind":"sparse","m":1,"n":1,"k":1}}"#,
        ErrorCode::UnknownOp,
    );
    // a known target this daemon does not serve
    expect_error(
        &mut client,
        r#"{"cmd":"tune","target":"v100","op":{"kind":"dense","m":8,"n":8,"k":8}}"#,
        ErrorCode::UnknownTarget,
    );
    // wrong-dimensionality coefficients must not panic the handler
    expect_error(
        &mut client,
        r#"{"cmd":"recalibrate","target":"graviton2","coeffs":[1.0,2.0]}"#,
        ErrorCode::BadCoeffs,
    );

    // after nine rejected requests, the same connection still works
    let op = OpSpec::Matmul { m: 16, n: 16, k: 16, epilogue: Epilogue::None };
    let ok = client.tune(TargetKind::Graviton2, op);
    assert!(
        matches!(ok, Response::Tuned { .. }),
        "connection unusable after malformed input: {ok:?}"
    );

    client.shutdown();
    daemon.join().unwrap();
}

#[test]
fn concurrent_warm_hammer_on_one_target_is_bit_identical_and_exactly_counted() {
    // the contention-audit acceptance test: many client threads hammering
    // one warm target must all get byte-identical answers (shared read
    // path, no LRU cross-talk) and the counters must come out exact
    let cfg = ServeConfig { threads: 4, ..base_config() };
    let (addr, daemon) = start_daemon(cfg);
    let op = OpSpec::Matmul { m: 40, n: 40, k: 20, epilogue: Epilogue::None };

    // warm the op: exactly one search, one miss
    let mut client = Client::connect(addr);
    let reference = client.tune(TargetKind::Graviton2, op);
    assert!(matches!(reference, Response::Tuned { cache_hit: false, .. }), "{reference:?}");

    const THREADS: usize = 8;
    const PER_THREAD: usize = 10;
    std::thread::scope(|s| {
        for _ in 0..THREADS {
            let want = &reference;
            s.spawn(move || {
                let mut c = Client::connect(addr);
                for _ in 0..PER_THREAD {
                    let got = c.tune(TargetKind::Graviton2, op);
                    let (Response::Tuned { config, predicted_cost, latency_s, cache_hit, .. },
                         Response::Tuned {
                             config: wc,
                             predicted_cost: wp,
                             latency_s: wl,
                             ..
                         }) = (&got, want)
                    else {
                        panic!("hammer tune failed: {got:?}");
                    };
                    assert!(*cache_hit, "warm hammer missed the cache");
                    assert_eq!(config, wc, "concurrent hit changed the schedule");
                    assert_eq!(predicted_cost, wp, "concurrent hit re-scored");
                    assert_eq!(latency_s, wl, "deployed-latency memo disagreed");
                }
            });
        }
    });

    let stats = client.stats_for(TargetKind::Graviton2);
    assert_eq!(stats.searches, 1, "a warm hit searched");
    assert_eq!(stats.misses, 1);
    assert_eq!(stats.hits, (THREADS * PER_THREAD) as u64, "hit counter lost updates");
    assert_eq!(stats.entries, 1);

    client.shutdown();
    daemon.join().unwrap();
}

#[test]
fn tune_net_over_the_socket_matches_single_op_tuning_and_fills_the_cache() {
    use tuna::serve::protocol::OpOutcome;
    let (addr, daemon) = start_daemon(base_config());
    let mut client = Client::connect(addr);
    let ops = vec![
        OpSpec::Matmul { m: 32, n: 32, k: 32, epilogue: Epilogue::None },
        OpSpec::Matmul { m: 64, n: 48, k: 16, epilogue: Epilogue::None },
        OpSpec::BatchMatmul { b: 4, m: 16, n: 16, k: 16 },
    ];

    let batch = Request::TuneNet {
        target: TargetKind::Graviton2,
        ops: ops.clone(),
        params: Some(tiny_params()),
    };
    let resp = client.send(&batch);
    let Response::TunedNet { target, results } = resp else { panic!("{resp:?}") };
    assert_eq!(target, TargetKind::Graviton2);
    assert_eq!(results.len(), ops.len());
    for (i, r) in results.iter().enumerate() {
        let OpOutcome::Tuned { op, cache_hit, evaluations, .. } = r else {
            panic!("ops[{i}] failed: {r:?}")
        };
        assert_eq!(*op, ops[i], "batch results out of request order");
        assert!(!cache_hit, "cold batch claimed a hit");
        assert!(*evaluations > 0);
    }
    let stats = client.stats_for(TargetKind::Graviton2);
    assert_eq!(stats.searches, ops.len() as u64);

    // the batch filled the same cache the single-op path reads: each op
    // re-tuned individually is a hit, bit-identical to its batch outcome
    for (i, r) in results.iter().enumerate() {
        let OpOutcome::Tuned { config, predicted_cost, latency_s, .. } = r else {
            unreachable!()
        };
        let single = client.tune(TargetKind::Graviton2, ops[i]);
        let Response::Tuned {
            cache_hit,
            config: sc,
            predicted_cost: sp,
            latency_s: sl,
            ..
        } = single
        else {
            panic!("single re-tune of ops[{i}] failed")
        };
        assert!(cache_hit, "ops[{i}]: batch did not warm the cache");
        assert_eq!(&sc, config, "ops[{i}]: single path diverged from batch");
        assert_eq!(sp, *predicted_cost);
        assert_eq!(sl, *latency_s, "ops[{i}]: deployed latency diverged");
    }
    assert_eq!(
        client.stats_for(TargetKind::Graviton2).searches,
        ops.len() as u64,
        "re-tunes after the batch searched"
    );

    // one bad op inside a batch: its slot fails, batch-mates still tune
    let mixed = client.send_raw(
        r#"{"cmd":"tune_net","target":"graviton2","ops":[{"kind":"dense","m":8,"n":8,"k":8},{"kind":"dense","m":0,"n":8,"k":8}]}"#,
    );
    match mixed {
        // decode-level rejection of the whole batch is also acceptable
        // only if typed; what must never happen is a dropped connection
        Response::Error { code, .. } => assert_eq!(code, ErrorCode::UnknownOp),
        Response::TunedNet { results, .. } => {
            assert_eq!(results.len(), 2);
            assert!(matches!(results[0], OpOutcome::Tuned { .. }));
            assert!(matches!(results[1], OpOutcome::Failed { .. }));
        }
        other => panic!("{other:?}"),
    }

    client.shutdown();
    daemon.join().unwrap();
}

#[test]
fn fused_tune_net_warm_hits_are_bit_identical_to_in_process_tuning() {
    use tuna::serve::protocol::OpOutcome;
    let (addr, daemon) = start_daemon(base_config());
    let mut client = Client::connect(addr);
    let base = OpSpec::Matmul { m: 32, n: 32, k: 16, epilogue: Epilogue::None };
    let ops = vec![
        base,
        base.with_epilogue(Epilogue::Bias).unwrap(),
        base.with_epilogue(Epilogue::BiasRelu).unwrap(),
    ];
    let batch = Request::TuneNet {
        target: TargetKind::Graviton2,
        ops: ops.clone(),
        params: Some(tiny_params()),
    };

    // cold batch: the fused variants are distinct tuning tasks of the
    // same shape — each gets its own search and cache entry
    let resp = client.send(&batch);
    let Response::TunedNet { results: cold, .. } = resp else { panic!("{resp:?}") };
    assert_eq!(cold.len(), ops.len());
    for (i, r) in cold.iter().enumerate() {
        let OpOutcome::Tuned { op, cache_hit, evaluations, .. } = r else {
            panic!("ops[{i}] failed: {r:?}")
        };
        assert_eq!(*op, ops[i], "batch results out of request order");
        assert!(!cache_hit, "cold fused batch claimed a hit (key collision?)");
        assert!(*evaluations > 0);
    }
    assert_eq!(client.stats_for(TargetKind::Graviton2).searches, ops.len() as u64);

    // repeat batch: every variant is a warm hit, zero re-search, and the
    // served schedules are byte-identical to the cold run
    let resp = client.send(&batch);
    let Response::TunedNet { results: warm, .. } = resp else { panic!("{resp:?}") };
    for (i, (c, w)) in cold.iter().zip(&warm).enumerate() {
        let (
            OpOutcome::Tuned { config, predicted_cost, latency_s, .. },
            OpOutcome::Tuned {
                config: wc,
                predicted_cost: wp,
                latency_s: wl,
                cache_hit,
                evaluations,
                ..
            },
        ) = (c, w)
        else {
            panic!("warm ops[{i}] failed: {w:?}")
        };
        assert!(*cache_hit, "ops[{i}]: warm fused batch missed the cache");
        assert_eq!(*evaluations, 0, "ops[{i}]: warm hit still evaluated");
        assert_eq!(wc, config, "ops[{i}]: warm hit changed the schedule");
        assert_eq!(wp, predicted_cost, "ops[{i}]: warm hit re-scored");
        assert_eq!(wl, latency_s, "ops[{i}]: deployed latency diverged");
    }
    let stats = client.stats_for(TargetKind::Graviton2);
    assert_eq!(stats.searches, ops.len() as u64, "warm fused batch searched");

    // every variant — fused included — matches in-process tuning with the
    // same model and search parameters, bit for bit
    let reference = Coordinator::new_uncalibrated(TargetKind::Graviton2);
    for (i, r) in cold.iter().enumerate() {
        let OpOutcome::Tuned { config, predicted_cost, .. } = r else { unreachable!() };
        let want = reference.tune_op(&ops[i], &Strategy::TunaStatic(tiny_es()));
        assert_eq!(config, &want.chosen, "ops[{i}]: served schedule diverged in-process");
        assert_eq!(*predicted_cost, want.top_k[0].1, "ops[{i}]: served cost diverged");
    }

    client.shutdown();
    daemon.join().unwrap();
}

#[test]
fn metrics_exposition_over_the_socket_counts_traffic_exactly() {
    let (addr, daemon) = start_daemon(base_config());
    let mut client = Client::connect(addr);
    let op = OpSpec::Matmul { m: 24, n: 24, k: 24, epilogue: Epilogue::None };

    // known traffic: 2 tunes (1 miss + 1 hit), 1 batch of the same op
    // (1 more hit), 1 garbage line, 1 stats
    assert!(matches!(
        client.tune(TargetKind::Graviton2, op),
        Response::Tuned { cache_hit: false, .. }
    ));
    assert!(matches!(
        client.tune(TargetKind::Graviton2, op),
        Response::Tuned { cache_hit: true, .. }
    ));
    let batch = client.send(&Request::TuneNet {
        target: TargetKind::Graviton2,
        ops: vec![op],
        params: Some(tiny_params()),
    });
    assert!(matches!(batch, Response::TunedNet { .. }), "{batch:?}");
    assert!(matches!(
        client.send_raw("not json"),
        Response::Error { code: ErrorCode::Parse, .. }
    ));
    let _ = client.stats_for(TargetKind::Graviton2);

    let resp = client.send(&Request::Metrics);
    let Response::Metrics { text } = resp else { panic!("{resp:?}") };
    for want in [
        "# TYPE tuna_serve_requests_total counter",
        "tuna_serve_requests_total{cmd=\"tune\"} 2",
        "tuna_serve_requests_total{cmd=\"tune_net\"} 1",
        "tuna_serve_requests_total{cmd=\"stats\"} 1",
        "tuna_serve_requests_total{cmd=\"metrics\"} 1",
        "tuna_serve_errors_total{code=\"parse\"} 1",
        "tuna_serve_ops_total{target=\"graviton2\",fused=\"false\"} 3",
        "tuna_serve_ops_total{target=\"graviton2\",fused=\"true\"} 0",
        "tuna_serve_op_cache_hits_total{target=\"graviton2\"} 2",
        "tuna_serve_op_cache_misses_total{target=\"graviton2\"} 1",
        "# TYPE tuna_serve_op_seconds histogram",
        "tuna_serve_op_seconds_bucket{target=\"graviton2\",le=\"+Inf\"} 3",
        "tuna_serve_op_seconds_count{target=\"graviton2\"} 3",
        "tuna_cache_entries{target=\"graviton2\"} 1",
        "tuna_searches_total{target=\"graviton2\"} 1",
    ] {
        assert!(text.contains(want), "missing {want:?} in exposition:\n{text}");
    }

    client.shutdown();
    daemon.join().unwrap();
}

#[test]
fn concurrent_tunes_on_different_targets_both_succeed() {
    let cfg = ServeConfig {
        targets: vec![TargetKind::Graviton2, TargetKind::CortexA53],
        threads: 2,
        calibrated: false,
        ..ServeConfig::default()
    };
    let (addr, daemon) = start_daemon(cfg);

    let tune_on = move |target: TargetKind| {
        std::thread::spawn(move || {
            let mut client = Client::connect(addr);
            let op = OpSpec::Matmul { m: 32, n: 32, k: 32, epilogue: Epilogue::None };
            let resp = client.tune(target, op);
            assert!(matches!(resp, Response::Tuned { cache_hit: false, .. }), "{resp:?}");
        })
    };
    let a = tune_on(TargetKind::Graviton2);
    let b = tune_on(TargetKind::CortexA53);
    a.join().unwrap();
    b.join().unwrap();

    let mut client = Client::connect(addr);
    let stats = client.send(&Request::Stats);
    let Response::Stats { targets } = stats else { panic!("stats failed") };
    assert_eq!(targets["graviton2"].searches, 1);
    assert_eq!(targets["a53"].searches, 1);
    client.shutdown();
    daemon.join().unwrap();
}

/// SIGKILL a journaling daemon process mid-flight, restart it from the
/// same journal, and every op tuned before the crash is served warm —
/// search-free, zero evaluations, bit-identical to the pre-crash
/// responses. The daemon is the real binary (`CARGO_BIN_EXE_tuna serve`)
/// so the kill is a real SIGKILL: no shutdown hook, no atexit save — the
/// interval journal sync is the only thing that survives.
#[test]
fn killed_daemon_restarts_from_journal_and_serves_pre_crash_hits() {
    use std::process::{Child, Command, Stdio};
    use std::time::Instant;
    use tuna::eval::CacheJournal;

    let journal = std::env::temp_dir()
        .join(format!("tuna_serve_e2e_crash_{}.tunaj", std::process::id()));
    let _ = std::fs::remove_file(&journal);

    struct Daemon(Option<Child>);
    impl Drop for Daemon {
        fn drop(&mut self) {
            if let Some(mut child) = self.0.take() {
                let _ = child.kill();
                let _ = child.wait();
            }
        }
    }

    fn spawn_daemon(journal: &std::path::Path) -> (Daemon, SocketAddr) {
        let mut child = Command::new(env!("CARGO_BIN_EXE_tuna"))
            .args(["serve", "--targets", "graviton2", "--port", "0", "--journal-every", "1"])
            .arg("--journal")
            .arg(journal)
            .stdout(Stdio::piped())
            .stderr(Stdio::null())
            .spawn()
            .expect("failed to spawn serve daemon");
        // "listening on 127.0.0.1:PORT"
        let stdout = child.stdout.take().expect("no stdout pipe");
        let mut line = String::new();
        BufReader::new(stdout).read_line(&mut line).expect("daemon stdout unreadable");
        let addr: SocketAddr = line
            .trim()
            .rsplit(' ')
            .next()
            .and_then(|a| a.parse().ok())
            .unwrap_or_else(|| panic!("no address in daemon banner {line:?}"));
        (Daemon(Some(child)), addr)
    }

    let ops = [
        OpSpec::Matmul { m: 40, n: 32, k: 24, epilogue: Epilogue::None },
        OpSpec::Matmul { m: 56, n: 32, k: 32, epilogue: Epilogue::None },
    ];

    // daemon A tunes both ops cold
    let (mut daemon_a, addr_a) = spawn_daemon(&journal);
    let mut client = Client::connect(addr_a);
    let mut pre_crash = Vec::new();
    for op in ops {
        let resp = client.tune(TargetKind::Graviton2, op);
        assert!(
            matches!(resp, Response::Tuned { cache_hit: false, .. }),
            "cold tune of {op} failed: {resp:?}"
        );
        pre_crash.push(resp);
    }

    // wait for the interval journaler to sync both entries (a concurrent
    // read can catch a torn tail — replay just drops it, so retry)
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        if let Ok(replay) = CacheJournal::replay(&journal) {
            if replay.records() >= ops.len() {
                break;
            }
        }
        assert!(Instant::now() < deadline, "journal never synced {} records", ops.len());
        std::thread::sleep(Duration::from_millis(100));
    }

    // SIGKILL: no graceful save path runs
    let mut child = daemon_a.0.take().expect("daemon already gone");
    child.kill().expect("kill failed");
    let status = child.wait().expect("wait failed");
    assert!(!status.success(), "SIGKILLed daemon exited 0");

    // daemon B: same journal, fresh process — replays at bind
    let (mut daemon_b, addr_b) = spawn_daemon(&journal);
    let mut client = Client::connect(addr_b);
    for (op, want) in ops.iter().zip(&pre_crash) {
        let got = client.tune(TargetKind::Graviton2, *op);
        let (
            Response::Tuned { cache_hit, evaluations, config, predicted_cost, latency_s, .. },
            Response::Tuned {
                config: want_config,
                predicted_cost: want_cost,
                latency_s: want_latency,
                ..
            },
        ) = (&got, want)
        else {
            panic!("post-restart tune of {op} failed: {got:?}");
        };
        assert!(*cache_hit, "{op} was lost in the crash");
        assert_eq!(*evaluations, 0, "{op} re-evaluated after restart");
        assert_eq!(config, want_config, "{op} schedule changed across the crash");
        assert_eq!(predicted_cost, want_cost, "{op} score changed across the crash");
        assert_eq!(latency_s, want_latency, "{op} deployed latency changed across the crash");
    }
    assert_eq!(
        client.stats_for(TargetKind::Graviton2).searches,
        0,
        "restarted daemon searched instead of replaying its journal"
    );

    // clean exit this time
    client.shutdown();
    let status = daemon_b.0.take().unwrap().wait().expect("daemon did not exit");
    assert!(status.success(), "daemon exited with {:?}", status.code());
    let _ = std::fs::remove_file(&journal);
}
