//! Fault-injection tests for the fleet conductor (`tuna tune-fleet`):
//! worker processes are killed, made to abort, and made to stall, and in
//! every case the campaign must finish with a merged cache **bit-identical**
//! to an unsharded `tune_network` run — same keys, same chosen configs,
//! same top-k, same evaluation counts. Workers are real OS processes
//! (`CARGO_BIN_EXE_tuna tune-shard`); the kill in the first test is a real
//! SIGKILL delivered mid-shard, not a cooperative shutdown.

use std::path::{Path, PathBuf};
use std::process::{Command, Stdio};
use std::time::{Duration, Instant};

use tuna::coordinator::{Coordinator, Strategy};
use tuna::eval::{CacheJournal, ScheduleCache};
use tuna::fleet::{
    run_fleet, shard_journal_path, FleetConfig, FAULT_AFTER_ENV, TASK_DELAY_ENV,
};
use tuna::graph::{all_networks, Network};
use tuna::isa::TargetKind;
use tuna::search::EsParams;
use tuna::shard::partition;

const KIND: TargetKind = TargetKind::Graviton2;
const WORKERS: usize = 2;

/// Must match [`worker_args`] exactly — the cache address embeds the
/// search signature, and bit-identity embeds everything else.
fn es() -> EsParams {
    EsParams { population: 8, iterations: 4, seed: 11, ..Default::default() }
}

fn worker_args() -> Vec<String> {
    ["--net", "bert_base", "--target", "graviton2", "--uncalibrated", "--pop", "8",
        "--iters", "4", "--seed", "11"]
        .into_iter()
        .map(String::from)
        .collect()
}

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_tuna")
}

fn work_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("tuna_fleet_{tag}_{}", std::process::id()))
}

/// The fused form — what `tune-shard` workers resolve `--net bert_base`
/// to, so the reference must tune the same task list.
fn fused_bert() -> Network {
    all_networks().into_iter().find(|n| n.name == "bert_base").expect("bert_base missing")
}

/// The unsharded ground truth: one process tunes every task, and its
/// exported cache serialization is the byte string the fleet's merged
/// cache file must equal.
fn reference_cache_text(net: &Network) -> String {
    let single = Coordinator::new_uncalibrated(KIND);
    single.tune_network(net, &Strategy::TunaStatic(es()));
    single.export_cache().to_json().to_string()
}

fn fleet_config(dir: &Path, out: &Path) -> FleetConfig {
    let mut cfg = FleetConfig::new(bin().into(), WORKERS, dir.to_path_buf(), out.to_path_buf());
    cfg.worker_args = worker_args();
    cfg.poll_interval = Duration::from_millis(50);
    cfg.backoff_base = Duration::from_millis(100);
    cfg
}

/// The shard a fault should land on: the one with the most tasks, so a
/// mid-shard kill always leaves both journaled and unjournaled work.
fn victim_shard(net: &Network) -> (usize, usize) {
    let tasks = net.unique_tasks();
    let parts = partition(KIND, &tasks, WORKERS);
    let (victim, part) =
        parts.iter().enumerate().max_by_key(|(_, p)| p.len()).expect("empty partition");
    assert!(part.len() >= 2, "victim shard too small to interrupt mid-shard");
    (victim, part.len())
}

/// SIGKILL a worker mid-shard, then let the conductor finish the campaign
/// over the same work dir: the respawn resumes from the journal (the
/// killed worker's completed searches are never repeated) and the merged
/// cache is bit-identical to unsharded tuning.
#[test]
fn sigkilled_worker_resumes_from_journal_and_merge_is_bit_identical() {
    let net = fused_bert();
    let (victim, victim_tasks) = victim_shard(&net);
    let dir = work_dir("sigkill");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let journal = shard_journal_path(&dir, victim);

    // a real worker process on the victim shard, slowed down so the kill
    // window between tasks is wide
    let mut worker = Command::new(bin())
        .args(["tune-shard", "--shards", &WORKERS.to_string(), "--shard", &victim.to_string()])
        .arg("--journal")
        .arg(&journal)
        .arg("--out")
        .arg(dir.join(format!("shard-{victim}.json")))
        .args(worker_args())
        .env(TASK_DELAY_ENV, "400")
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .expect("failed to spawn tune-shard worker");

    // wait for at least one flushed record, then SIGKILL — no flush, no
    // save, no goodbye
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        let records =
            CacheJournal::replay(&journal).map(|r| r.records()).unwrap_or(0);
        if records >= 1 {
            break;
        }
        assert!(Instant::now() < deadline, "worker journaled nothing in 120s");
        std::thread::sleep(Duration::from_millis(25));
    }
    worker.kill().expect("kill failed");
    let status = worker.wait().expect("wait failed");
    assert!(!status.success(), "SIGKILLed worker exited 0");
    let survivors = CacheJournal::replay(&journal).unwrap().records();
    assert!(survivors >= 1, "no complete record survived the kill");
    assert!(survivors < victim_tasks, "worker finished before the kill landed");

    // the campaign over the same work dir: the victim's respawn replays
    // the journal and only searches what the dead worker never finished
    let out = dir.join("merged.json");
    let report = run_fleet(&fleet_config(&dir, &out)).expect("fleet did not recover");
    assert_eq!(report.merged_entries, net.unique_tasks().len());

    // every complete pre-kill record was resumed, not re-searched: one
    // journal record per task, ever
    assert_eq!(CacheJournal::replay(&journal).unwrap().records(), victim_tasks);

    let merged = std::fs::read_to_string(&out).unwrap();
    assert_eq!(merged, reference_cache_text(&net), "merged cache diverged from unsharded run");

    // the merged file round-trips as a first-class cache
    assert_eq!(ScheduleCache::load(&out).unwrap().len(), net.unique_tasks().len());
    let _ = std::fs::remove_dir_all(&dir);
}

/// An injected abort (the CI smoke's fault knob) on a first attempt is
/// retried with backoff; the retry resumes and the merge is still
/// bit-identical.
#[test]
fn injected_abort_is_retried_and_merge_is_bit_identical() {
    let net = fused_bert();
    let (victim, _) = victim_shard(&net);
    let dir = work_dir("abort");
    let _ = std::fs::remove_dir_all(&dir);
    let out = dir.join("merged.json");

    let mut cfg = fleet_config(&dir, &out);
    // the victim's first attempt aborts right after its first journal
    // append; the retry runs clean (first-attempt-only injection)
    cfg.first_attempt_env = vec![(victim, FAULT_AFTER_ENV.to_string(), "1".to_string())];
    let report = run_fleet(&cfg).expect("fleet did not survive the injected abort");

    assert!(report.retries() >= 1, "no retry recorded: {report:?}");
    assert!(report.shards[victim].attempts >= 2, "victim was not respawned: {report:?}");
    assert_eq!(report.reassignments(), 0, "abort was misclassified as a stall");
    assert_eq!(report.merged_entries, net.unique_tasks().len());

    let merged = std::fs::read_to_string(&out).unwrap();
    assert_eq!(merged, reference_cache_text(&net), "retried campaign diverged");
    let _ = std::fs::remove_dir_all(&dir);
}

/// A worker that stalls (alive but journaling nothing) past the heartbeat
/// deadline is killed and its shard reassigned; the campaign still
/// completes with a full, bit-identical merge.
#[test]
fn stalled_worker_is_reassigned_past_the_heartbeat_deadline() {
    let net = fused_bert();
    let (victim, _) = victim_shard(&net);
    let dir = work_dir("straggler");
    let _ = std::fs::remove_dir_all(&dir);
    let out = dir.join("merged.json");

    let mut cfg = fleet_config(&dir, &out);
    // the victim's first attempt sleeps 60s after each task — it will
    // journal once, then stall far past the 3s heartbeat deadline
    cfg.first_attempt_env = vec![(victim, TASK_DELAY_ENV.to_string(), "60000".to_string())];
    cfg.heartbeat_timeout = Duration::from_secs(3);
    cfg.poll_interval = Duration::from_millis(100);
    let report = run_fleet(&cfg).expect("fleet did not recover from the straggler");

    assert!(report.reassignments() >= 1, "straggler was never reassigned: {report:?}");
    assert!(report.shards[victim].attempts >= 2, "victim was not respawned: {report:?}");
    assert_eq!(report.merged_entries, net.unique_tasks().len());

    let merged = std::fs::read_to_string(&out).unwrap();
    assert_eq!(merged, reference_cache_text(&net), "reassigned campaign diverged");
    let _ = std::fs::remove_dir_all(&dir);
}
