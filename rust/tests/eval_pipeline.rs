//! Integration tests for the staged candidate-evaluation pipeline:
//! batched-vs-sequential score equivalence on CPU and GPU targets,
//! coefficient-swap re-scoring from the memoized feature store (no
//! re-lowering), the schedule cache's JSON round trip, bounded-cache
//! eviction, cross-process reuse, cache-hit behaviour of repeated
//! `tune_network` runs, and typed-error propagation through the batched
//! search instead of mid-search panics.

use tuna::analysis::cost::{extract_gpu, CostError};
use tuna::coordinator::{Coordinator, Strategy};
use tuna::eval::{CachedSchedule, CandidateEvaluator, ScheduleCache};
use tuna::graph::{Layer, Network};
use tuna::isa::march::tesla_v100;
use tuna::isa::{AsmProgram, TargetKind};
use tuna::search::{BatchObjective, EsParams, EvolutionStrategies};
use tuna::tir::ops::{Epilogue, OpSpec};
use tuna::transform::{self, ScheduleConfig};
use tuna::CostModel;

fn tiny_es() -> EsParams {
    EsParams { population: 12, iterations: 6, k: 10, seed: 5, ..Default::default() }
}

fn sample_cfgs(op: &OpSpec, kind: TargetKind, n: u64) -> Vec<ScheduleConfig> {
    let space = transform::config_space(op, kind);
    let n = n.min(space.size()).max(1);
    (0..n).map(|i| space.from_index(i * space.size() / n)).collect()
}

/// Batched scores must be bit-identical to per-candidate
/// `CostModel::predict` on a CPU target — the acceptance bar for routing
/// every search through the evaluator.
#[test]
fn batched_scores_bit_identical_cpu() {
    let kind = TargetKind::Graviton2;
    let cm = CostModel::with_default_coeffs(kind);
    let ev = CandidateEvaluator::new(cm.clone());
    let op = OpSpec::Conv2d {
        n: 1, cin: 8, h: 14, w: 14, cout: 16, kh: 3, kw: 3, stride: 1, pad: 1,
        epilogue: Epilogue::None,
    };
    let cfgs = sample_cfgs(&op, kind, 32);
    let batched = ev.score_batch(&op, &cfgs);
    let sequential: Vec<f64> = cfgs.iter().map(|c| cm.predict(&op, c)).collect();
    assert_eq!(batched, sequential, "batched CPU scores diverged from predict");
    // memoized second pass returns the same bits
    assert_eq!(ev.score_batch(&op, &cfgs), sequential);
    assert!(ev.stats().hits >= cfgs.len() as u64);
}

/// Same equivalence on a GPU target (exercises the `extract_gpu` Result
/// path end to end).
#[test]
fn batched_scores_bit_identical_gpu() {
    let kind = TargetKind::TeslaV100;
    let cm = CostModel::with_default_coeffs(kind);
    let ev = CandidateEvaluator::new(cm.clone());
    let op = OpSpec::Matmul { m: 128, n: 128, k: 64, epilogue: Epilogue::None };
    let cfgs = sample_cfgs(&op, kind, 32);
    let batched = ev.score_batch(&op, &cfgs);
    let sequential: Vec<f64> = cfgs.iter().map(|c| cm.predict(&op, c)).collect();
    assert_eq!(batched, sequential, "batched GPU scores diverged from predict");
}

/// A GPU program with no launch metadata is a typed error, not a panic.
#[test]
fn missing_launch_is_typed_error() {
    let kind = TargetKind::TeslaV100;
    let op = OpSpec::Matmul { m: 64, n: 64, k: 32, epilogue: Epilogue::None };
    let space = transform::config_space(&op, kind);
    let f = transform::apply(&op, kind, &space.default_config());
    let gpu = tesla_v100();
    let bare = AsmProgram::new(); // never lowered: no launch config
    match extract_gpu(&f, &bare, &gpu) {
        Err(CostError::MissingLaunch { func }) => assert_eq!(func, f.name),
        other => panic!("expected MissingLaunch, got {other:?}"),
    }
}

/// Typed evaluation failures propagate out of the batched ES search
/// instead of crashing the thread pool.
#[test]
fn search_propagates_eval_errors() {
    struct Failing;
    impl BatchObjective for Failing {
        fn eval_batch(&self, _cfgs: &[ScheduleConfig]) -> Result<Vec<f64>, CostError> {
            Err(CostError::MissingLaunch { func: "synthetic".into() })
        }
    }
    let op = OpSpec::Matmul { m: 32, n: 32, k: 32, epilogue: Epilogue::None };
    let space = transform::config_space(&op, TargetKind::Graviton2);
    let r = EvolutionStrategies::new(tiny_es()).run_batched(&space, &Failing);
    assert_eq!(r.unwrap_err(), CostError::MissingLaunch { func: "synthetic".into() });
}

/// Schedule-cache JSON round trip through a real tuning outcome.
#[test]
fn schedule_cache_roundtrips_through_json() {
    let kind = TargetKind::Graviton2;
    let c = Coordinator::new_uncalibrated(kind);
    let op = OpSpec::Matmul { m: 48, n: 48, k: 24, epilogue: Epilogue::None };
    let rep = c.tune_op(&op, &Strategy::TunaStatic(tiny_es()));

    let space = transform::config_space(&op, kind);
    let sig = Strategy::TunaStatic(tiny_es()).cache_sig().unwrap();
    let key = ScheduleCache::key(kind, &op, &space, &sig);
    let mut cache = ScheduleCache::new();
    cache.insert(
        key.clone(),
        CachedSchedule {
            chosen: rep.chosen.clone(),
            best_score: rep.top_k[0].1,
            top_k: rep.top_k.clone(),
            evaluations: rep.evaluations,
            op: Some(op),
        },
    );

    let path = std::env::temp_dir().join(format!("tuna_cache_rt_{}.json", std::process::id()));
    cache.save(&path).unwrap();
    let back = ScheduleCache::load(&path).unwrap();
    let _ = std::fs::remove_file(&path);

    assert_eq!(back.len(), 1);
    let entry = back.peek(&key).expect("key survived the round trip");
    assert_eq!(entry.chosen, rep.chosen);
    assert_eq!(entry.top_k, rep.top_k, "top-k scores must round-trip bit-exactly");
    assert_eq!(entry.evaluations, rep.evaluations);
}

fn toy_net() -> Network {
    Network {
        name: "cache_toy",
        display: "CacheToy",
        layers: vec![
            Layer::single(OpSpec::Matmul { m: 64, n: 64, k: 64, epilogue: Epilogue::None }, 2),
            Layer::single(OpSpec::Matmul { m: 64, n: 32, k: 64, epilogue: Epilogue::None }, 1),
            Layer::single(
                OpSpec::DepthwiseConv2d {
                    n: 1, c: 16, h: 14, w: 14, kh: 3, kw: 3, stride: 1, pad: 1,
                    epilogue: Epilogue::None,
                },
                1,
            ),
        ],
    }
}

/// Second `tune_network` on the same coordinator performs zero searches:
/// every task is served by the schedule cache, identically and much
/// faster.
#[test]
fn second_tune_network_performs_zero_searches() {
    let c = Coordinator::new_uncalibrated(TargetKind::Graviton2);
    let net = toy_net();
    let strategy = Strategy::TunaStatic(tiny_es());

    let first = c.tune_network(&net, &strategy);
    let searches_after_first = c.searches_performed();
    assert_eq!(searches_after_first, net.unique_tasks().len() as u64);
    assert_eq!(first.cache_hits, 0);

    let second = c.tune_network(&net, &strategy);
    assert_eq!(c.searches_performed(), searches_after_first, "second run searched");
    assert_eq!(second.cache_hits, net.unique_tasks().len() as u64);
    assert_eq!(second.latency_s, first.latency_s, "cached deployment diverged");
    for (key, rep) in &second.per_op {
        assert!(rep.cache_hit, "{key} missed the cache");
        assert_eq!(rep.evaluations, 0);
        assert_eq!(rep.chosen, first.per_op[key].chosen);
    }
    // the cached pass skips all ES generations, so it is far faster; keep
    // the CI assertion conservative (the bench reports the real margin,
    // typically orders of magnitude)
    assert!(
        second.wall_s < first.wall_s / 2.0,
        "cached re-run not faster: {} vs {}",
        second.wall_s,
        first.wall_s
    );
}

/// The persisted cache carries schedules across coordinators — the
/// cross-process reuse path (`save_cache` in one process, `load_cache` in
/// the next, zero searches after).
#[test]
fn persisted_cache_skips_searches_across_coordinators() {
    let net = toy_net();
    let strategy = Strategy::TunaStatic(tiny_es());
    let path = std::env::temp_dir().join(format!("tuna_cache_xp_{}.json", std::process::id()));

    let first = Coordinator::new_uncalibrated(TargetKind::Graviton2);
    let rep1 = first.tune_network(&net, &strategy);
    first.save_cache(&path).unwrap();
    assert!(first.searches_performed() > 0);

    let second = Coordinator::new_uncalibrated(TargetKind::Graviton2);
    let resident = second.load_cache(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    assert_eq!(resident, net.unique_tasks().len());
    let rep2 = second.tune_network(&net, &strategy);
    assert_eq!(second.searches_performed(), 0, "loaded cache did not serve the tasks");
    assert_eq!(rep2.cache_hits, net.unique_tasks().len() as u64);
    assert_eq!(rep2.latency_s, rep1.latency_s);
    for (key, rep) in &rep2.per_op {
        assert_eq!(rep.chosen, rep1.per_op[key].chosen, "{key} deployed a different schedule");
    }
}

/// The recalibration contract, CPU: an evaluator that swaps coefficients
/// after a batch must score bit-identically to a fresh evaluator built
/// with those coefficients — and the swap path must not re-lower anything
/// (feature-memo miss count unchanged).
#[test]
fn swap_coeffs_matches_fresh_evaluator_cpu() {
    let kind = TargetKind::Graviton2;
    let ev = CandidateEvaluator::new(CostModel::with_default_coeffs(kind));
    let op = OpSpec::Conv2d {
        n: 1, cin: 8, h: 14, w: 14, cout: 16, kh: 3, kw: 3, stride: 1, pad: 1,
        epilogue: Epilogue::None,
    };
    let cfgs = sample_cfgs(&op, kind, 24);
    ev.score_batch(&op, &cfgs);
    let misses_before = ev.stats().misses;

    let coeffs = vec![0.7, 1.3, 0.2, 2.0, 0.9, 5.0, 0.4];
    ev.swap_coeffs(coeffs.clone());
    let swapped = ev.score_batch(&op, &cfgs);
    assert_eq!(ev.stats().misses, misses_before, "swap path re-lowered candidates");

    let fresh = CandidateEvaluator::new(CostModel::with_coeffs(kind, coeffs.clone()));
    assert_eq!(swapped, fresh.score_batch(&op, &cfgs), "swapped scores diverged from fresh");
    // and both agree with the one-call model API
    let cm = CostModel::with_coeffs(kind, coeffs);
    let sequential: Vec<f64> = cfgs.iter().map(|c| cm.predict(&op, c)).collect();
    assert_eq!(swapped, sequential);
}

/// Same recalibration contract on a GPU target.
#[test]
fn swap_coeffs_matches_fresh_evaluator_gpu() {
    let kind = TargetKind::TeslaV100;
    let ev = CandidateEvaluator::new(CostModel::with_default_coeffs(kind));
    let op = OpSpec::Matmul { m: 128, n: 128, k: 64, epilogue: Epilogue::None };
    let cfgs = sample_cfgs(&op, kind, 24);
    ev.score_batch(&op, &cfgs);
    let misses_before = ev.stats().misses;

    let coeffs = vec![1.5, 0.8, 2.0, 0.1, 0.6, 3.0];
    ev.swap_coeffs(coeffs.clone());
    let swapped = ev.score_batch(&op, &cfgs);
    assert_eq!(ev.stats().misses, misses_before, "GPU swap path re-lowered candidates");

    let fresh = CandidateEvaluator::new(CostModel::with_coeffs(kind, coeffs));
    assert_eq!(swapped, fresh.score_batch(&op, &cfgs), "GPU swapped scores diverged");
}

/// `recalibrate` through the evaluator is bit-identical to calibrating a
/// bare `CostModel` on the same samples.
#[test]
fn recalibrate_matches_bare_model_calibration() {
    let kind = TargetKind::Graviton2;
    let ev = CandidateEvaluator::new(CostModel::with_default_coeffs(kind));
    let op = OpSpec::Matmul { m: 48, n: 48, k: 48, epilogue: Epilogue::None };
    let cfgs = sample_cfgs(&op, kind, 20);
    // synthetic ground truth over memoized features
    let samples: Vec<_> = cfgs
        .iter()
        .map(|c| {
            let fv = ev.try_features(&op, c).unwrap();
            let y = 3.0 * fv.values[0] + 7.0 * fv.values[5] + 1.0;
            (fv, y)
        })
        .collect();
    ev.recalibrate(&samples);

    let mut cm = CostModel::with_default_coeffs(kind);
    cm.calibrate(&samples);
    assert_eq!(ev.coeffs(), cm.coeffs(), "refit diverged from bare calibrate");
    let batch = ev.score_batch(&op, &cfgs);
    let sequential: Vec<f64> = cfgs.iter().map(|c| cm.predict(&op, c)).collect();
    assert_eq!(batch, sequential);
}

/// Multi-model scoring: several coefficient vectors over one set of
/// lowered features, each bit-identical to a dedicated model, with zero
/// extra lowering.
#[test]
fn score_batch_with_scores_many_models_from_one_feature_pass() {
    let kind = TargetKind::Graviton2;
    let ev = CandidateEvaluator::new(CostModel::with_default_coeffs(kind));
    let op = OpSpec::Matmul { m: 64, n: 32, k: 32, epilogue: Epilogue::None };
    let cfgs = sample_cfgs(&op, kind, 16);
    ev.score_batch(&op, &cfgs); // the one feature pass
    let misses_before = ev.stats().misses;
    for variant in 1..=3u32 {
        let coeffs: Vec<f64> = (0..7).map(|i| (i as f64 + 0.5) * variant as f64).collect();
        let got = ev.score_batch_with(&coeffs, &op, &cfgs);
        let cm = CostModel::with_coeffs(kind, coeffs);
        let want: Vec<f64> = cfgs.iter().map(|c| cm.predict(&op, c)).collect();
        assert_eq!(got, want, "variant {variant} diverged");
    }
    assert_eq!(ev.stats().misses, misses_before, "multi-model pass re-lowered");
}

/// A coordinator's recalibration stage re-ranks its cached entries under
/// the new coefficients without invalidating the cache: the next request
/// is still a hit and deploys the re-chosen schedule.
#[test]
fn coordinator_recalibration_rescores_cache_without_new_searches() {
    let c = Coordinator::new_uncalibrated(TargetKind::Graviton2);
    let op = OpSpec::Matmul { m: 64, n: 64, k: 64, epilogue: Epilogue::None };
    let strategy = Strategy::TunaStatic(tiny_es());
    let first = c.tune_op(&op, &strategy);
    assert!(!first.cache_hit);

    let coeffs = vec![0.2, 1.1, 0.4, 2.2, 0.3, 6.0, 0.8];
    let reranked = c.swap_coeffs(coeffs.clone());
    assert_eq!(reranked, 1);

    let second = c.tune_op(&op, &strategy);
    assert!(second.cache_hit, "recalibration invalidated the cache");
    assert_eq!(c.searches_performed(), 1);
    let cm = CostModel::with_coeffs(TargetKind::Graviton2, coeffs);
    for (cfg, s) in &second.top_k {
        assert_eq!(*s, cm.predict(&op, cfg), "cached top-k not re-scored");
    }
    assert!(second.top_k.windows(2).all(|w| w[0].1 <= w[1].1));
    assert_eq!(second.chosen, second.top_k[0].0);
}

/// A bounded schedule cache under tuning churn: never exceeds its cap,
/// reports evictions, survives a JSON save/load round trip, and an
/// evicted task falls back to a fresh (deterministic) search.
#[test]
fn bounded_cache_evicts_and_falls_back_to_search() {
    let c = Coordinator::new_uncalibrated(TargetKind::Graviton2);
    c.set_cache_capacity(Some(2));
    let strategy = Strategy::TunaStatic(tiny_es());
    let ops = [
        OpSpec::Matmul { m: 32, n: 32, k: 32, epilogue: Epilogue::None },
        OpSpec::Matmul { m: 48, n: 32, k: 32, epilogue: Epilogue::None },
        OpSpec::Matmul { m: 64, n: 32, k: 32, epilogue: Epilogue::None },
        OpSpec::Matmul { m: 96, n: 32, k: 32, epilogue: Epilogue::None },
    ];
    let first: Vec<_> = ops.iter().map(|op| c.tune_op(op, &strategy)).collect();
    let (entries, _, _) = c.cache_stats();
    assert_eq!(entries, 2, "cap breached");
    assert_eq!(c.cache_evictions(), 2);

    // the bounded cache still round-trips its resident entries
    let path = std::env::temp_dir().join(format!("tuna_cache_ev_{}.json", std::process::id()));
    c.save_cache(&path).unwrap();
    let back = ScheduleCache::load(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    assert_eq!(back.len(), 2);

    // evicted task: miss → fresh search → same deterministic outcome
    let searches_before = c.searches_performed();
    let again = c.tune_op(&ops[0], &strategy);
    assert!(!again.cache_hit, "evicted entry served");
    assert_eq!(c.searches_performed(), searches_before + 1);
    assert_eq!(again.chosen, first[0].chosen, "re-search diverged");
}

/// Different targets never share cache entries even for the same op.
#[test]
fn cache_keys_isolate_targets() {
    let op = OpSpec::Matmul { m: 32, n: 32, k: 32, epilogue: Epilogue::None };
    let sig = "es_x";
    let g = transform::config_space(&op, TargetKind::Graviton2);
    let x = transform::config_space(&op, TargetKind::XeonPlatinum8124M);
    assert_ne!(
        ScheduleCache::key(TargetKind::Graviton2, &op, &g, sig),
        ScheduleCache::key(TargetKind::XeonPlatinum8124M, &op, &x, sig)
    );
}
