//! Table II — entire-network compilation time, AutoTVM vs Tuna.
//!
//! AutoTVM's cost = host wall + *virtual device seconds* (compile + RPC +
//! timed repeats per measurement, sequential device); Tuna's cost = host
//! wall only. The paper's headline: up to 339× compile-time speedup.
//!
//! ```bash
//! cargo bench --bench table2_compile_time
//! ```

mod common;

use tuna::coordinator::Strategy;

fn main() {
    for kind in common::targets() {
        let nets = common::networks();
        let (results, coords) = common::run_all_strategies_fresh(kind, &nets);
        let (names, displays) = common::names_displays(&nets);
        println!("{}", tuna::metrics::table2(kind, &results, &names, &displays));

        for net in &names {
            let tuna = &results["Tuna"][*net];
            let full = &results["AutoTVM Full"][*net];
            println!(
                "  {net}: tuna {:.2}s (device 0s) vs autotvm {:.2}s (device {:.2}s) -> {:.0}x",
                tuna.compile_seconds(),
                full.compile_seconds(),
                full.device_s,
                full.compile_seconds() / tuna.compile_seconds().max(1e-9)
            );
        }

        // repeated compilation on each network's own coordinator: every
        // task is already in its schedule cache, so the second pass skips
        // all searches
        for (net, c) in nets.iter().zip(&coords) {
            let searches_before = c.searches_performed();
            let first = results["Tuna"][net.name].compile_seconds();
            let rerun = c.tune_network(net, &Strategy::TunaStatic(common::es_params()));
            assert_eq!(
                c.searches_performed(),
                searches_before,
                "cached re-run of {} still searched",
                net.name
            );
            println!(
                "  {}: cached re-run {:.4}s vs first {:.2}s -> {:.0}x ({} hits)",
                net.name,
                rerun.compile_seconds(),
                first,
                first / rerun.compile_seconds().max(1e-9),
                rerun.cache_hits
            );
        }
    }
}
