//! Table II — entire-network compilation time, AutoTVM vs Tuna.
//!
//! AutoTVM's cost = host wall + *virtual device seconds* (compile + RPC +
//! timed repeats per measurement, sequential device); Tuna's cost = host
//! wall only. The paper's headline: up to 339× compile-time speedup.
//!
//! ```bash
//! cargo bench --bench table2_compile_time
//! ```

mod common;

fn main() {
    for kind in common::targets() {
        let nets = common::networks();
        let results = common::run_all_strategies(kind, &nets);
        let (names, displays) = common::names_displays(&nets);
        println!("{}", tuna::metrics::table2(kind, &results, &names, &displays));

        for net in &names {
            let tuna = &results["Tuna"][*net];
            let full = &results["AutoTVM Full"][*net];
            println!(
                "  {net}: tuna {:.2}s (device 0s) vs autotvm {:.2}s (device {:.2}s) -> {:.0}x",
                tuna.compile_seconds(),
                full.compile_seconds(),
                full.device_s,
                full.compile_seconds() / tuna.compile_seconds().max(1e-9)
            );
        }
    }
}
