//! Shared bench harness (the offline environment has no criterion, so the
//! `harness = false` benches are plain binaries built on this module).
//!
//! Environment knobs, all optional:
//!   TUNA_BENCH_TARGETS   comma list (default: xeon,graviton2 for CPU-only
//!                        benches, all five where GPUs are meaningful)
//!   TUNA_BENCH_NETS      comma list of networks (default: all four)
//!   TUNA_BENCH_TRIALS    AutoTVM-Full measurement budget (default 64)
//!   TUNA_BENCH_FAST      "1" = small ES populations for smoke runs

// each bench compiles this module separately and uses a subset of it
#![allow(dead_code)]

use std::collections::BTreeMap;
use std::time::Instant;

use tuna::coordinator::{Coordinator, NetworkReport, Strategy};
use tuna::graph::{all_networks, Network};
use tuna::isa::TargetKind;
use tuna::search::EsParams;

pub fn targets() -> Vec<TargetKind> {
    match std::env::var("TUNA_BENCH_TARGETS") {
        Ok(s) => tuna::config::parse_targets(&s).expect("TUNA_BENCH_TARGETS"),
        Err(_) => TargetKind::ALL.to_vec(),
    }
}

pub fn networks() -> Vec<Network> {
    let nets = all_networks();
    match std::env::var("TUNA_BENCH_NETS") {
        Ok(s) => nets
            .into_iter()
            .filter(|n| s.split(',').any(|x| x.trim() == n.name))
            .collect(),
        Err(_) => nets,
    }
}

pub fn trials() -> u64 {
    std::env::var("TUNA_BENCH_TRIALS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64)
}

pub fn es_params() -> EsParams {
    if std::env::var("TUNA_BENCH_FAST").as_deref() == Ok("1") {
        EsParams { population: 12, iterations: 6, ..Default::default() }
    } else {
        EsParams { population: 24, iterations: 10, ..Default::default() }
    }
}

/// Run all four strategies over the selected networks on one coordinator
/// (callers that also want to probe the schedule cache construct the
/// coordinator themselves and pass it in).
/// Returns results["<strategy>"]["<network>"].
pub fn run_all_strategies_on(
    c: &Coordinator,
    nets: &[Network],
) -> BTreeMap<String, BTreeMap<String, NetworkReport>> {
    let kind = c.kind;
    let mut results: BTreeMap<String, BTreeMap<String, NetworkReport>> = BTreeMap::new();
    for net in nets {
        let t0 = Instant::now();
        eprintln!("  [{:?}] {} ...", kind, net.name);
        let tuna = c.tune_network(net, &Strategy::TunaStatic(es_params()));
        let budget = c.partial_budget_per_op(&tuna);
        let partial = c.tune_network(net, &Strategy::AutoTvmPartial { budget_s: budget });
        let full = c.tune_network(net, &Strategy::AutoTvmFull { trials: trials() });
        let vendor = c.tune_network(net, &Strategy::Vendor);
        eprintln!("    done in {:.1}s wall", t0.elapsed().as_secs_f64());
        results.entry("Tuna".into()).or_default().insert(net.name.into(), tuna);
        results
            .entry("AutoTVM Partial".into())
            .or_default()
            .insert(net.name.into(), partial);
        results.entry("AutoTVM Full".into()).or_default().insert(net.name.into(), full);
        results.entry("Framework".into()).or_default().insert(net.name.into(), vendor);
    }
    results
}

/// Paper-methodology runner: a *fresh* coordinator (empty schedule cache)
/// per network, so each network's compile time includes all of its own
/// search work even when networks share task shapes (the SSD pair does).
/// Cross-network cache reuse is demonstrated explicitly by table2's
/// cached re-run, not baked silently into the first-run numbers. Returns
/// each network's coordinator (in `nets` order) alongside the results so
/// callers can probe the warm caches afterwards.
pub fn run_all_strategies_fresh(
    kind: TargetKind,
    nets: &[Network],
) -> (BTreeMap<String, BTreeMap<String, NetworkReport>>, Vec<Coordinator>) {
    let mut results: BTreeMap<String, BTreeMap<String, NetworkReport>> = BTreeMap::new();
    let mut coords = Vec::new();
    for net in nets {
        let c = Coordinator::new(kind);
        let one = run_all_strategies_on(&c, std::slice::from_ref(net));
        for (strategy, by_net) in one {
            results.entry(strategy).or_default().extend(by_net);
        }
        coords.push(c);
    }
    (results, coords)
}

/// Results-only form of [`run_all_strategies_fresh`].
pub fn run_all_strategies(
    kind: TargetKind,
    nets: &[Network],
) -> BTreeMap<String, BTreeMap<String, NetworkReport>> {
    run_all_strategies_fresh(kind, nets).0
}

pub fn names_displays(nets: &[Network]) -> (Vec<&str>, Vec<&str>) {
    (
        nets.iter().map(|n| n.name).collect(),
        nets.iter().map(|n| n.display).collect(),
    )
}
