//! Figure 3 — top-10 performance ratio, single operators, Tuna vs AutoTVM.
//!
//! For each operator: Tuna's static search picks its top-10; AutoTVM's
//! measured tuner picks its top-10; both sets are then executed on the
//! device and `Σ autotvm / Σ tuna` is reported (paper: ~0.869 average,
//! values approaching 1 = the static model selects as well as measuring).
//!
//! ```bash
//! cargo bench --bench fig3_top10_ratio
//! TUNA_BENCH_TARGETS=v100 cargo bench --bench fig3_top10_ratio
//! ```

mod common;

use tuna::coordinator::Coordinator;
use tuna::metrics;

fn main() {
    let k = 10usize;
    for kind in common::targets() {
        let c = Coordinator::new(kind);
        let mut entries = Vec::new();
        for op in tuna::tir::ops::figure_op_suite() {
            let ratio = metrics::topk_sweep_ratio(&c, &op, k, common::trials());
            eprintln!("  [{kind:?}] {op}: {ratio:.3}");
            entries.push((op.to_string(), ratio));
        }
        println!(
            "{}",
            metrics::figure_topk(
                &format!("Figure 3: top-{k} performance ratio — {}", kind.display_name()),
                &entries
            )
        );
    }
}
