//! Shard-scaling bench — the paper's distributed-compilation claim made
//! quantitative on the Table-I networks: because candidate evaluation is
//! static (no device in the loop), tuning work partitions over N workers
//! and the only serial step left is merging their schedule caches.
//!
//! Each worker is timed running its deterministic partition with the full
//! host to itself (workers on separate machines don't share cores, so
//! running them back-to-back and reporting `max(worker wall)` is the
//! honest N-machine wall-clock; `sum(worker wall)` is the single-machine
//! cost). Speedup = single-process total / max worker wall. Efficiency
//! falls off exactly as far as the hash partition is unbalanced — small
//! task sets (BERT: 6 tasks) plateau early, the SSD networks (~dozens of
//! tasks) stay near-linear.
//!
//! Every shard count also merges the worker caches, serves the whole
//! network from the merged cache with zero searches, and asserts the
//! deployment is identical to the single-process outcome.
//!
//! ```bash
//! cargo bench --bench shard_scaling
//! TUNA_BENCH_FAST=1 TUNA_BENCH_NETS=bert_base TUNA_BENCH_TARGETS=graviton2 \
//!     cargo bench --bench shard_scaling
//! ```

mod common;

use std::time::Instant;
use tuna::coordinator::{Coordinator, NetworkReport, Strategy};
use tuna::shard::{self, ShardWorker};

fn main() {
    for kind in common::targets() {
        for net in common::networks() {
            let tasks = net.unique_tasks();
            let strategy = Strategy::TunaStatic(common::es_params());
            let model = tuna::coordinator::calibrate::calibrated_model(kind);
            println!(
                "== shard scaling: {} on {} ({} tasks) ==",
                net.name,
                kind.display_name(),
                tasks.len()
            );

            let mut single_total = 0.0_f64;
            let mut reference: Option<NetworkReport> = None;
            for n in [1usize, 2, 4, 8] {
                let shards = shard::partition(kind, &tasks, n);
                let occupied = shards.iter().filter(|s| !s.is_empty()).count();

                // workers run back-to-back, each with the whole host (as
                // they would on N separate machines); per-worker wall
                // times give both the N-machine and 1-machine clocks
                let mut worker_walls = Vec::new();
                let mut caches = Vec::new();
                for (id, shard_tasks) in shards.iter().enumerate() {
                    let worker = ShardWorker::with_model(id, kind, model.clone());
                    let t0 = Instant::now();
                    worker.run(shard_tasks, &strategy);
                    worker_walls.push(t0.elapsed().as_secs_f64());
                    caches.push(worker.into_cache());
                }
                let total: f64 = worker_walls.iter().sum();
                let wall = worker_walls.iter().cloned().fold(0.0, f64::max);
                if n == 1 {
                    single_total = total;
                }

                // merge + serve: the whole network from the merged cache,
                // zero searches, identical to the single-process tune
                let (merged, stats) = shard::merge_caches(caches);
                assert_eq!(stats.combined, 0, "disjoint partition clashed at n={n}");
                assert_eq!(merged.len(), tasks.len());
                let serving = Coordinator::with_model(kind, model.clone());
                serving.import_cache(merged);
                let rep = serving.tune_network(&net, &strategy);
                assert_eq!(
                    serving.searches_performed(),
                    0,
                    "merged cache incomplete at n={n}"
                );
                match &reference {
                    None => reference = Some(rep),
                    Some(want) => assert_eq!(
                        rep.latency_s, want.latency_s,
                        "n={n} deployment diverged from single-process"
                    ),
                }

                let speedup = if wall > 0.0 { single_total / wall } else { 1.0 };
                println!(
                    "  shards {n:>2} (occupied {occupied:>2})  1-machine {total:>8.2}s  \
                     N-machine wall {wall:>8.2}s  speedup {speedup:>5.2}x  \
                     efficiency {:>5.1}%",
                    100.0 * speedup / n as f64
                );
            }
        }
    }
}
