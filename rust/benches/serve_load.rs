//! Serving-throughput bench — `tuna bench-serve` as a cargo bench target.
//!
//! Boots a real daemon per selected target/network, hammers it with
//! concurrent clients through single-op / batched / mixed phases (see
//! `tuna::serve::bench`), prints the per-phase table, and writes the last
//! run's report to `BENCH_serve_load.json` at the repo root.
//!
//! ```bash
//! cargo bench --bench serve_load
//! TUNA_BENCH_FAST=1 TUNA_BENCH_NETS=bert_base TUNA_BENCH_TARGETS=graviton2 \
//!     cargo bench --bench serve_load
//! ```

mod common;

use tuna::serve::bench::{self, BenchConfig};
use tuna::serve::protocol::TuneParams;

fn main() {
    let fast = std::env::var("TUNA_BENCH_FAST").as_deref() == Ok("1");
    for kind in common::targets() {
        for net in common::networks() {
            let mut cfg = BenchConfig::new(kind, net.unique_tasks());
            cfg.params = TuneParams::from_es(&common::es_params());
            if fast {
                cfg.clients = 4;
                cfg.requests_per_client = 16;
                cfg.batches_per_client = 4;
            }
            println!(
                "== serve load: {} on {} ({} ops, {} clients, {} serve threads) ==",
                net.name,
                kind.display_name(),
                cfg.ops.len(),
                cfg.clients,
                cfg.serve_threads
            );
            let report = bench::run(&cfg).expect("bench run failed");
            for p in &report.phases {
                assert_eq!(p.errors, 0, "{}: error responses under load", p.label);
                println!(
                    "  {:<8} requests {:>6}  ops {:>6}  p50 {:>9.1} us  p99 {:>9.1} us  \
                     {:>8.0} req/s  {:>8.0} ops/s",
                    p.label, p.requests, p.ops, p.p50_us, p.p99_us, p.rps, p.ops_per_s
                );
            }
            if let Some(s) = report.batched_speedup() {
                println!("  batched/single op throughput: {s:.2}x");
            }
            let mut text = bench::report_json(&report).to_string();
            text.push('\n');
            std::fs::write("BENCH_serve_load.json", text).expect("write BENCH_serve_load.json");
        }
    }
}
