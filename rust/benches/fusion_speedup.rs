//! Fusion-speedup bench — the graph-level epilogue-fusion acceptance
//! numbers: each paper network is deployed twice on the same coordinator,
//! once with fused candidates offered (the default `all_networks` form)
//! and once fusion-forbidden (`fuse::strip`), and every layer decides
//! fused-vs-unfused by measured latency. The stripped run goes first, so
//! the fused run serves every unfused task from the cache — the two
//! deployments price shared tasks identically and the delta is purely
//! the fusion decisions. Writes `BENCH_fusion.json` at the repo root.
//!
//! ```bash
//! cargo bench --bench fusion_speedup
//! TUNA_BENCH_FAST=1 TUNA_BENCH_NETS=bert_base cargo bench --bench fusion_speedup
//! ```

mod common;

use std::collections::BTreeMap;

use tuna::coordinator::{Coordinator, NetworkReport, Strategy};
use tuna::graph::{fuse, EpilogueTask, Network};
use tuna::isa::TargetKind;
use tuna::tir::ops::Epilogue;
use tuna::util::json::Json;

struct Row {
    network: &'static str,
    unfused_s: f64,
    fused_s: f64,
    speedup: f64,
    layers_fused: usize,
    layers_fusable: usize,
    layers_total: usize,
}

/// Recompute the per-layer deployment decisions exactly the way
/// `Network::latency` makes them: min over viable alternatives, with
/// unfused ones charged the measured standalone epilogue pass. Returns
/// (layers deployed fused, layers that declare a fusable tail).
fn fused_layer_count(c: &Coordinator, net: &Network, rep: &NetworkReport) -> (usize, usize) {
    let mut task_latency: BTreeMap<String, f64> =
        rep.per_op.iter().map(|(k, r)| (k.clone(), r.latency_s)).collect();
    for t in net.epilogue_tasks() {
        task_latency.insert(t.key.clone(), c.device.run_epilogue(&t).seconds);
    }
    let mut fused = 0usize;
    let mut fusable = 0usize;
    for l in &net.layers {
        if l.epilogue == Epilogue::None {
            continue;
        }
        fusable += 1;
        let pass = EpilogueTask::for_layer(l).and_then(|t| task_latency.get(&t.key).copied());
        let mut best = f64::MAX;
        let mut best_fused = false;
        for op in &l.alternatives {
            let Some(&own) = task_latency.get(&op.cache_key()) else { continue };
            let cost = if op.epilogue() == l.epilogue {
                own
            } else if op.epilogue() == Epilogue::None {
                match pass {
                    Some(p) => own + p,
                    None => continue,
                }
            } else {
                continue;
            };
            if cost < best {
                best = cost;
                best_fused = op.is_fused();
            }
        }
        fused += best_fused as usize;
    }
    (fused, fusable)
}

fn main() {
    let kind = match std::env::var("TUNA_BENCH_TARGETS") {
        Ok(s) => *tuna::config::parse_targets(&s)
            .expect("TUNA_BENCH_TARGETS")
            .first()
            .expect("TUNA_BENCH_TARGETS is empty"),
        Err(_) => TargetKind::Graviton2,
    };
    let c = Coordinator::new_uncalibrated(kind);
    let strategy = Strategy::TunaStatic(common::es_params());

    println!(
        "## Fusion speedup on {} (per-layer deploy by measured latency)\n",
        kind.display_name()
    );
    println!(
        "{:<16} {:>12} {:>12} {:>9} {:>14}",
        "network", "unfused ms", "fused ms", "speedup", "layers fused"
    );
    let mut rows = Vec::new();
    for net in common::networks() {
        // fusion-forbidden baseline first: the fused run below then hits
        // the cache for every shared unfused task
        let stripped = fuse::strip(&net);
        let unfused = c.tune_network(&stripped, &strategy);
        let fused_rep = c.tune_network(&net, &strategy);
        assert!(
            fused_rep.latency_s <= unfused.latency_s + 1e-12,
            "{}: offering fused candidates made deployment slower",
            net.name
        );
        let (layers_fused, layers_fusable) = fused_layer_count(&c, &net, &fused_rep);
        let speedup = unfused.latency_s / fused_rep.latency_s;
        println!(
            "{:<16} {:>12.3} {:>12.3} {:>8.3}x {:>11}/{}",
            net.name,
            unfused.latency_s * 1e3,
            fused_rep.latency_s * 1e3,
            speedup,
            layers_fused,
            layers_fusable
        );
        rows.push(Row {
            network: net.name,
            unfused_s: unfused.latency_s,
            fused_s: fused_rep.latency_s,
            speedup,
            layers_fused,
            layers_fusable,
            layers_total: net.layers.len(),
        });
    }

    // the PR's acceptance bar, checked whenever the full set runs
    if rows.len() == 4 {
        let faster = rows.iter().filter(|r| r.speedup > 1.0).count();
        assert!(faster >= 2, "fused deployment strictly faster on only {faster}/4 networks");
    }

    let networks = Json::Arr(
        rows.iter()
            .map(|r| {
                Json::obj(vec![
                    ("network", Json::Str(r.network.into())),
                    ("unfused_latency_s", Json::Num(r.unfused_s)),
                    ("fused_latency_s", Json::Num(r.fused_s)),
                    ("speedup", Json::Num(r.speedup)),
                    ("layers_fused", Json::Num(r.layers_fused as f64)),
                    ("layers_fusable", Json::Num(r.layers_fusable as f64)),
                    ("layers_total", Json::Num(r.layers_total as f64)),
                ])
            })
            .collect(),
    );
    let doc = Json::obj(vec![
        ("bench", Json::Str("fusion_speedup".into())),
        ("target", Json::Str(kind.wire_name().into())),
        (
            "provenance",
            Json::Str(
                "measured by `cargo bench --bench fusion_speedup`; regenerate in place \
                 with the same command (the CI fusion smoke step runs the \
                 TUNA_BENCH_FAST=1 TUNA_BENCH_NETS=bert_base form and validates the \
                 schema)"
                    .into(),
            ),
        ),
        ("networks", networks),
    ]);
    let mut text = doc.to_string();
    text.push('\n');
    std::fs::write("BENCH_fusion.json", text).expect("write BENCH_fusion.json");
    println!("\nwrote BENCH_fusion.json");
}
