//! Table III — compilation cost in dollars (cloud targets only:
//! c5.9xlarge $1.53/hr, m6g.4xlarge $0.616/hr, p3.2xlarge $3.06/hr).
//!
//! cost = compile_seconds / 3600 × instance price. The paper's claim:
//! Tuna reduces compile cost to ~1.1% of AutoTVM's.
//!
//! ```bash
//! cargo bench --bench table3_compile_cost
//! ```

mod common;

fn main() {
    for kind in common::targets() {
        if kind.dollars_per_hour().is_none() {
            println!("(skipping {} — edge device, no cloud price)\n", kind.display_name());
            continue;
        }
        let nets = common::networks();
        let results = common::run_all_strategies(kind, &nets);
        let (names, displays) = common::names_displays(&nets);
        if let Some(t3) = tuna::metrics::table3(kind, &results, &names, &displays) {
            println!("{t3}");
        }
        // cost-fraction summary
        let mut tuna_total = 0.0;
        let mut atvm_total = 0.0;
        for net in &names {
            tuna_total += results["Tuna"][*net].compile_seconds();
            atvm_total += results["AutoTVM Full"][*net].compile_seconds();
        }
        println!(
            "  Tuna cost fraction: {:.2}% of AutoTVM (paper: ~1.1%)\n",
            tuna_total / atvm_total * 100.0
        );
    }
}
