//! Figure 4 — top-50 performance ratio, single operators, Tuna vs AutoTVM.
//!
//! Same protocol as Figure 3 with k=50 (paper: ~0.873 average).
//!
//! ```bash
//! cargo bench --bench fig4_top50_ratio
//! ```

mod common;

use tuna::coordinator::Coordinator;
use tuna::metrics;

fn main() {
    let k = 50usize;
    for kind in common::targets() {
        let c = Coordinator::new(kind);
        let mut entries = Vec::new();
        for op in tuna::tir::ops::figure_op_suite() {
            let ratio = metrics::topk_sweep_ratio(&c, &op, k, common::trials());
            eprintln!("  [{kind:?}] {op}: {ratio:.3}");
            entries.push((op.to_string(), ratio));
        }
        println!(
            "{}",
            metrics::figure_topk(
                &format!("Figure 4: top-{k} performance ratio — {}", kind.display_name()),
                &entries
            )
        );
    }
}
