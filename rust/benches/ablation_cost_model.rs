//! Ablation — which cost-model features earn their keep? (DESIGN.md §7)
//!
//! For each CPU feature, zero its coefficient and measure the drop in
//! rank correlation (Spearman) between static scores and device ground
//! truth across a held-out operator set. Also compares calibrated vs
//! latency-table-default coefficients, and ES vs random vs exhaustive
//! search quality under the same evaluation budget.
//!
//! ```bash
//! cargo bench --bench ablation_cost_model
//! ```

mod common;

use tuna::analysis::cost::CPU_FEATURES;
use tuna::analysis::CostModel;
use tuna::coordinator::calibrate;
use tuna::isa::TargetKind;
use tuna::search::{self, EsParams, EvolutionStrategies};
use tuna::sim::Device;
use tuna::tir::ops::OpSpec;
use tuna::util::stats::spearman;

fn rank_corr(cm: &CostModel, device: &Device, ops: &[OpSpec], n_cfg: u64) -> f64 {
    let mut rhos = Vec::new();
    for op in ops {
        let space = tuna::transform::config_space(op, cm.kind);
        let mut preds = Vec::new();
        let mut truths = Vec::new();
        for i in 0..space.size().min(n_cfg) {
            let cfg = space.from_index(i * space.size() / space.size().min(n_cfg));
            preds.push(cm.predict(op, &cfg));
            truths.push(device.run(op, &cfg).seconds);
        }
        rhos.push(spearman(&preds, &truths));
    }
    rhos.iter().sum::<f64>() / rhos.len() as f64
}

fn main() {
    let kind = TargetKind::Graviton2;
    let device = Device::new(kind);
    let ops = [
        OpSpec::Matmul { m: 128, n: 128, k: 128 },
        OpSpec::Conv2d { n: 1, cin: 32, h: 28, w: 28, cout: 32, kh: 3, kw: 3, stride: 1, pad: 1 },
        OpSpec::DepthwiseConv2d { n: 1, c: 48, h: 28, w: 28, kh: 3, kw: 3, stride: 1, pad: 1 },
    ];

    println!("## Ablation: cost-model features ({})\n", kind.display_name());
    let full = calibrate::calibrated_model(kind);
    let base_rho = rank_corr(&full, &device, &ops, 32);
    println!("{:<28} {:>10}", "variant", "rank-corr");
    println!("{:<28} {:>10.3}", "calibrated (all features)", base_rho);

    let defaults = CostModel::with_default_coeffs(kind);
    println!(
        "{:<28} {:>10.3}",
        "latency-table defaults",
        rank_corr(&defaults, &device, &ops, 32)
    );

    for (i, name) in CPU_FEATURES.iter().enumerate() {
        let mut ablated = full.clone();
        ablated.coeffs[i] = 0.0;
        let rho = rank_corr(&ablated, &device, &ops, 32);
        println!("{:<28} {:>10.3}  (delta {:+.3})", format!("- {name}"), rho, rho - base_rho);
    }

    // ---- search-algorithm ablation at equal evaluation budget ----
    println!("\n## Ablation: search algorithm (budget = 240 static evals)\n");
    let op = ops[1];
    let space = tuna::transform::config_space(&op, kind);
    let cm = full.clone();
    let obj = move |cfg: &tuna::transform::ScheduleConfig| cm.predict(&op, cfg);
    let es = EvolutionStrategies::new(EsParams {
        population: 24,
        iterations: 10,
        ..Default::default()
    })
    .run(&space, &obj);
    let rnd = search::random_search(&space, &obj, 240, 10, 1, 7);
    let exh = search::exhaustive(&space, &obj, 10, tuna::util::pool::default_threads());
    println!("{:<28} {:>14} {:>12}", "algorithm", "best score", "measured ms");
    for (name, r) in [("evolution strategies", &es), ("random search", &rnd), ("exhaustive", &exh)]
    {
        let lat = device.run(&op, &r.best).seconds;
        println!("{:<28} {:>14.0} {:>12.4}", name, r.best_score, lat * 1e3);
    }
}
