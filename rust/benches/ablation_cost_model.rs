//! Ablation — which cost-model features earn their keep? (DESIGN.md §7)
//!
//! For each CPU feature, zero its coefficient and measure the drop in
//! rank correlation (Spearman) between static scores and device ground
//! truth across a held-out operator set. Also compares calibrated vs
//! latency-table-default coefficients, and ES vs random vs exhaustive
//! search quality under the same evaluation budget.
//!
//! Every coefficient variant is scored from **one** feature pass: the
//! candidates are lowered and analyzed once into the evaluator's memoized
//! feature store, and each variant is then a batch of dot products
//! (`score_batch_with`). The bench reports the measured gap — re-scoring a
//! variant is orders of magnitude cheaper than the feature pass it reuses.
//!
//! ```bash
//! cargo bench --bench ablation_cost_model
//! ```

mod common;

use std::time::Instant;

use tuna::analysis::cost::CPU_FEATURES;
use tuna::analysis::CostModel;
use tuna::coordinator::calibrate;
use tuna::eval::CandidateEvaluator;
use tuna::isa::TargetKind;
use tuna::search::{self, EsParams, EvolutionStrategies};
use tuna::sim::Device;
use tuna::tir::ops::{Epilogue, OpSpec};
use tuna::transform::ScheduleConfig;
use tuna::util::stats::spearman;

/// Held-out candidate grid + device ground truth for one operator.
struct Task {
    op: OpSpec,
    cfgs: Vec<ScheduleConfig>,
    truths: Vec<f64>,
}

fn mean_rank_corr(tasks: &[Task], per_op_scores: &[Vec<f64>]) -> f64 {
    let rhos: Vec<f64> = tasks
        .iter()
        .zip(per_op_scores)
        .map(|(t, scores)| spearman(scores, &t.truths))
        .collect();
    rhos.iter().sum::<f64>() / rhos.len() as f64
}

fn main() {
    let kind = TargetKind::Graviton2;
    let device = Device::new(kind);
    let ops = [
        OpSpec::Matmul { m: 128, n: 128, k: 128, epilogue: Epilogue::None },
        OpSpec::Conv2d {
            n: 1, cin: 32, h: 28, w: 28, cout: 32, kh: 3, kw: 3, stride: 1, pad: 1,
            epilogue: Epilogue::None,
        },
        OpSpec::DepthwiseConv2d {
            n: 1, c: 48, h: 28, w: 28, kh: 3, kw: 3, stride: 1, pad: 1,
            epilogue: Epilogue::None,
        },
    ];

    // one evaluator holds the calibrated scorer and the shared feature store
    let ev = CandidateEvaluator::new(calibrate::calibrated_model(kind));
    let base_coeffs = ev.coeffs();

    let tasks: Vec<Task> = ops
        .iter()
        .map(|&op| {
            let space = tuna::transform::config_space(&op, kind);
            let n = space.size().min(32);
            let cfgs: Vec<ScheduleConfig> =
                (0..n).map(|i| space.from_index(i * space.size() / n)).collect();
            let truths = cfgs.iter().map(|c| device.run(&op, c).seconds).collect();
            Task { op, cfgs, truths }
        })
        .collect();

    // ---- stage 1, exactly once: lower + analyze every candidate ----
    let t0 = Instant::now();
    let base_scores: Vec<Vec<f64>> =
        tasks.iter().map(|t| ev.score_batch(&t.op, &t.cfgs)).collect();
    let feature_pass_ms = t0.elapsed().as_secs_f64() * 1e3;
    let lowered = ev.stats().misses;

    println!("## Ablation: cost-model features ({})\n", kind.display_name());
    let base_rho = mean_rank_corr(&tasks, &base_scores);
    println!("{:<28} {:>10}", "variant", "rank-corr");
    println!("{:<28} {:>10.3}", "calibrated (all features)", base_rho);

    // every variant below is pure stage-2 work over the same features
    let mut variants: Vec<(String, Vec<f64>)> = vec![(
        "latency-table defaults".into(),
        CostModel::with_default_coeffs(kind).coeffs().to_vec(),
    )];
    for (i, name) in CPU_FEATURES.iter().enumerate() {
        let mut coeffs = base_coeffs.clone();
        coeffs[i] = 0.0;
        variants.push((format!("- {name}"), coeffs));
    }

    let t1 = Instant::now();
    let variant_scores: Vec<Vec<Vec<f64>>> = variants
        .iter()
        .map(|(_, coeffs)| {
            tasks.iter().map(|t| ev.score_batch_with(coeffs, &t.op, &t.cfgs)).collect()
        })
        .collect();
    let rescore_ms = t1.elapsed().as_secs_f64() * 1e3;
    assert_eq!(ev.stats().misses, lowered, "variant scoring re-lowered candidates");

    for ((name, _), scores) in variants.iter().zip(&variant_scores) {
        let rho = mean_rank_corr(tasks.as_slice(), scores);
        println!("{:<28} {:>10.3}  (delta {:+.3})", name, rho, rho - base_rho);
    }

    let per_variant_ms = (rescore_ms / variants.len() as f64).max(1e-9);
    println!(
        "\nfeature pass (lower + analyze, {lowered} candidates): {feature_pass_ms:>10.2} ms"
    );
    println!(
        "re-score per coefficient variant (memoized features):  {per_variant_ms:>10.4} ms",
    );
    println!(
        "  -> {:.0}x cheaper than the feature pass ({} variants in {rescore_ms:.3} ms)",
        feature_pass_ms / per_variant_ms,
        variants.len(),
    );

    // ---- search-algorithm ablation at equal evaluation budget ----
    println!("\n## Ablation: search algorithm (budget = 240 static evals)\n");
    let op = tasks[1].op;
    let space = tuna::transform::config_space(&op, kind);
    let obj = ev.objective(&op);
    let es = EvolutionStrategies::new(EsParams {
        population: 24,
        iterations: 10,
        ..Default::default()
    })
    .run_batched(&space, &obj)
    .expect("es search");
    let rnd = search::random_search_batched(&space, &obj, 240, 10, 7).expect("random search");
    let exh = search::exhaustive_batched(&space, &obj, 10).expect("exhaustive sweep");
    println!("{:<28} {:>14} {:>12}", "algorithm", "best score", "measured ms");
    for (name, r) in [("evolution strategies", &es), ("random search", &rnd), ("exhaustive", &exh)]
    {
        let lat = device.run(&op, &r.best).seconds;
        println!("{:<28} {:>14.0} {:>12.4}", name, r.best_score, lat * 1e3);
    }
}
