//! Ablation — which parts of the cost model earn their keep? (DESIGN.md §7)
//!
//! Three studies:
//!
//! 1. **Scorer family × target.** Every registered scorer (latency-table
//!    linear defaults, calibrated linear, the offline-trained quadratic)
//!    is evaluated on every selected backend against device ground truth
//!    over a held-out operator grid, reporting Spearman rank correlation
//!    per scorer per target. Written to `BENCH_scorer_ablation.json` at
//!    the repo root; the run fails if the learned quadratic scorer does
//!    not match or beat the calibrated linear scorer on at least one
//!    target.
//! 2. **Per-feature ablation** (Graviton2): zero each CPU feature's
//!    coefficient and measure the rank-correlation drop. Every variant is
//!    scored from **one** feature pass — candidates are lowered and
//!    analyzed once into the evaluator's memoized feature store, and each
//!    variant is then a batch of dot products (`score_batch_with`).
//! 3. **Search-algorithm ablation**: ES vs random vs exhaustive at an
//!    equal static-evaluation budget.
//!
//! ```bash
//! cargo bench --bench ablation_cost_model
//! TUNA_BENCH_FAST=1 cargo bench --bench ablation_cost_model   # 2 targets
//! ```

mod common;

use std::time::Instant;

use tuna::analysis::cost::CPU_FEATURES;
use tuna::analysis::{CostModel, ScorerSpec};
use tuna::coordinator::calibrate;
use tuna::eval::CandidateEvaluator;
use tuna::isa::TargetKind;
use tuna::search::{self, EsParams, EvolutionStrategies};
use tuna::sim::Device;
use tuna::tir::ops::{Epilogue, OpSpec};
use tuna::transform::ScheduleConfig;
use tuna::util::json::Json;
use tuna::util::stats::spearman;

/// Held-out candidate grid + device ground truth for one operator.
struct Task {
    op: OpSpec,
    cfgs: Vec<ScheduleConfig>,
    truths: Vec<f64>,
}

/// Held-out operators — disjoint from the calibration micro-suite.
fn held_out_ops() -> [OpSpec; 3] {
    [
        OpSpec::Matmul { m: 128, n: 128, k: 128, epilogue: Epilogue::None },
        OpSpec::Conv2d {
            n: 1, cin: 32, h: 28, w: 28, cout: 32, kh: 3, kw: 3, stride: 1, pad: 1,
            epilogue: Epilogue::None,
        },
        OpSpec::DepthwiseConv2d {
            n: 1, c: 48, h: 28, w: 28, kh: 3, kw: 3, stride: 1, pad: 1,
            epilogue: Epilogue::None,
        },
    ]
}

/// Build the held-out grid for `kind`: strided samples of each op's own
/// config space, priced once on the device simulator.
fn held_out_tasks(kind: TargetKind, grid: u64) -> Vec<Task> {
    let device = Device::new(kind);
    held_out_ops()
        .iter()
        .map(|&op| {
            let space = tuna::transform::config_space(&op, kind);
            let n = space.size().min(grid);
            let cfgs: Vec<ScheduleConfig> =
                (0..n).map(|i| space.from_index(i * space.size() / n)).collect();
            let truths = cfgs.iter().map(|c| device.run(&op, c).seconds).collect();
            Task { op, cfgs, truths }
        })
        .collect()
}

fn mean_rank_corr(tasks: &[Task], per_op_scores: &[Vec<f64>]) -> f64 {
    let rhos: Vec<f64> = tasks
        .iter()
        .zip(per_op_scores)
        .map(|(t, scores)| spearman(scores, &t.truths))
        .collect();
    rhos.iter().sum::<f64>() / rhos.len() as f64
}

/// Targets for the scorer study. `TUNA_BENCH_TARGETS` wins; the FAST
/// smoke keeps one CPU and the RISC-V backend; otherwise all six.
fn scorer_targets() -> Vec<TargetKind> {
    if std::env::var("TUNA_BENCH_TARGETS").is_ok() {
        return common::targets();
    }
    if std::env::var("TUNA_BENCH_FAST").as_deref() == Ok("1") {
        vec![TargetKind::Graviton2, TargetKind::SiFiveU74]
    } else {
        TargetKind::ALL.to_vec()
    }
}

/// One scorer variant of the study: display/wire name plus its model for
/// a given target.
fn scorer_variants(kind: TargetKind) -> Vec<(&'static str, CostModel)> {
    vec![
        ("linear-default", CostModel::with_default_coeffs(kind)),
        ("linear-calibrated", calibrate::calibrated_model(kind)),
        (
            "quadratic",
            CostModel::with_scorer(kind, calibrate::calibrated_scorer(kind, ScorerSpec::Quadratic)),
        ),
    ]
}

/// Study 1: rank correlation per scorer per target, persisted as
/// `BENCH_scorer_ablation.json`.
fn scorer_ablation() {
    let grid = if std::env::var("TUNA_BENCH_FAST").as_deref() == Ok("1") { 16 } else { 32 };
    println!("## Ablation: scorer family x target (held-out ops, grid {grid})\n");
    println!("{:<16} {:<20} {:>10}", "target", "scorer", "rank-corr");

    let mut target_docs = Vec::new();
    let mut learned_wins = Vec::new();
    for kind in scorer_targets() {
        let tasks = held_out_tasks(kind, grid);
        let mut rows = Vec::new();
        for (name, model) in scorer_variants(kind) {
            let scores: Vec<Vec<f64>> = tasks
                .iter()
                .map(|t| t.cfgs.iter().map(|c| model.predict(&t.op, c)).collect())
                .collect();
            let rho = mean_rank_corr(&tasks, &scores);
            assert!(rho.is_finite() && (-1.0..=1.0).contains(&rho), "{name} on {kind:?}: {rho}");
            println!("{:<16} {:<20} {:>10.3}", kind.wire_name(), name, rho);
            rows.push((name, rho));
        }
        let of = |n: &str| rows.iter().find(|(name, _)| *name == n).unwrap().1;
        if of("quadratic") >= of("linear-calibrated") {
            learned_wins.push(kind.wire_name());
        }
        target_docs.push(Json::obj(vec![
            ("target", Json::Str(kind.wire_name().into())),
            ("held_out_ops", Json::Num(held_out_ops().len() as f64)),
            (
                "scorers",
                Json::Arr(
                    rows.iter()
                        .map(|(name, rho)| {
                            Json::obj(vec![
                                ("scorer", Json::Str((*name).into())),
                                ("rank_corr", Json::Num(*rho)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]));
    }

    let doc = Json::obj(vec![
        ("bench", Json::Str("scorer_ablation".into())),
        (
            "provenance",
            Json::Str(
                "measured by `cargo bench --bench ablation_cost_model`; regenerate in \
                 place with the same command (the CI learned-scorer smoke runs the \
                 TUNA_BENCH_FAST=1 form and validates the schema)"
                    .into(),
            ),
        ),
        ("targets", Json::Arr(target_docs)),
    ]);
    let mut text = doc.to_string();
    text.push('\n');
    std::fs::write("BENCH_scorer_ablation.json", text).expect("write BENCH_scorer_ablation.json");
    println!("\nwrote BENCH_scorer_ablation.json");

    // the PR's acceptance bar: the learned scorer earns its place by
    // ranking at least one backend no worse than the calibrated linear fit
    assert!(
        !learned_wins.is_empty(),
        "quadratic scorer beat linear-calibrated on no target at all"
    );
    println!("learned scorer >= linear-calibrated on: {}\n", learned_wins.join(", "));
}

fn main() {
    scorer_ablation();

    let kind = TargetKind::Graviton2;
    let device = Device::new(kind);

    // one evaluator holds the calibrated scorer and the shared feature store
    let ev = CandidateEvaluator::new(calibrate::calibrated_model(kind));
    let base_coeffs = ev.coeffs();

    let tasks = held_out_tasks(kind, 32);

    // ---- stage 1, exactly once: lower + analyze every candidate ----
    let t0 = Instant::now();
    let base_scores: Vec<Vec<f64>> =
        tasks.iter().map(|t| ev.score_batch(&t.op, &t.cfgs)).collect();
    let feature_pass_ms = t0.elapsed().as_secs_f64() * 1e3;
    let lowered = ev.stats().misses;

    println!("## Ablation: cost-model features ({})\n", kind.display_name());
    let base_rho = mean_rank_corr(&tasks, &base_scores);
    println!("{:<28} {:>10}", "variant", "rank-corr");
    println!("{:<28} {:>10.3}", "calibrated (all features)", base_rho);

    // every variant below is pure stage-2 work over the same features
    let mut variants: Vec<(String, Vec<f64>)> = vec![(
        "latency-table defaults".into(),
        CostModel::with_default_coeffs(kind).coeffs().to_vec(),
    )];
    for (i, name) in CPU_FEATURES.iter().enumerate() {
        let mut coeffs = base_coeffs.clone();
        coeffs[i] = 0.0;
        variants.push((format!("- {name}"), coeffs));
    }

    let t1 = Instant::now();
    let variant_scores: Vec<Vec<Vec<f64>>> = variants
        .iter()
        .map(|(_, coeffs)| {
            tasks.iter().map(|t| ev.score_batch_with(coeffs, &t.op, &t.cfgs)).collect()
        })
        .collect();
    let rescore_ms = t1.elapsed().as_secs_f64() * 1e3;
    assert_eq!(ev.stats().misses, lowered, "variant scoring re-lowered candidates");

    for ((name, _), scores) in variants.iter().zip(&variant_scores) {
        let rho = mean_rank_corr(tasks.as_slice(), scores);
        println!("{:<28} {:>10.3}  (delta {:+.3})", name, rho, rho - base_rho);
    }

    let per_variant_ms = (rescore_ms / variants.len() as f64).max(1e-9);
    println!(
        "\nfeature pass (lower + analyze, {lowered} candidates): {feature_pass_ms:>10.2} ms"
    );
    println!(
        "re-score per coefficient variant (memoized features):  {per_variant_ms:>10.4} ms",
    );
    println!(
        "  -> {:.0}x cheaper than the feature pass ({} variants in {rescore_ms:.3} ms)",
        feature_pass_ms / per_variant_ms,
        variants.len(),
    );

    // ---- search-algorithm ablation at equal evaluation budget ----
    println!("\n## Ablation: search algorithm (budget = 240 static evals)\n");
    let op = tasks[1].op;
    let space = tuna::transform::config_space(&op, kind);
    let obj = ev.objective(&op);
    let es = EvolutionStrategies::new(EsParams {
        population: 24,
        iterations: 10,
        ..Default::default()
    })
    .run_batched(&space, &obj)
    .expect("es search");
    let rnd = search::random_search_batched(&space, &obj, 240, 10, 7).expect("random search");
    let exh = search::exhaustive_batched(&space, &obj, 10).expect("exhaustive sweep");
    println!("{:<28} {:>14} {:>12}", "algorithm", "best score", "measured ms");
    for (name, r) in [("evolution strategies", &es), ("random search", &rnd), ("exhaustive", &exh)]
    {
        let lat = device.run(&op, &r.best).seconds;
        println!("{:<28} {:>14.0} {:>12.4}", name, r.best_score, lat * 1e3);
    }
}
