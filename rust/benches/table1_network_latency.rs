//! Table I — entire-network inference latency per target, for
//! {Framework, AutoTVM Partial, AutoTVM Full, Tuna}.
//!
//! Reproduces the paper's Table I(a-e) shape: Tuna within ~±10% of
//! AutoTVM-Full, far ahead of AutoTVM-Partial at equal compile budget,
//! and ahead of the Framework row on most cells.
//!
//! ```bash
//! cargo bench --bench table1_network_latency
//! TUNA_BENCH_FAST=1 TUNA_BENCH_NETS=bert_base cargo bench --bench table1_network_latency
//! ```

mod common;

fn main() {
    for kind in common::targets() {
        let nets = common::networks();
        let results = common::run_all_strategies(kind, &nets);
        let (names, displays) = common::names_displays(&nets);
        println!("{}", tuna::metrics::table1(kind, &results, &names, &displays));

        // paper-shape assertions (soft: printed, not panicking, so partial
        // runs still emit their tables)
        for net in &names {
            let tuna = &results["Tuna"][*net];
            let full = &results["AutoTVM Full"][*net];
            let partial = &results["AutoTVM Partial"][*net];
            let ratio_full = full.latency_s / tuna.latency_s;
            let ratio_partial = partial.latency_s / tuna.latency_s;
            println!(
                "  {net}: tuna/full retained {:.1}%  partial-speedup {:.2}x",
                ratio_full * 100.0,
                ratio_partial
            );
        }
    }
}
