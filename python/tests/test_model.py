"""L2 model-vs-reference tests: MLP block and im2col conv path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def _rand(shape, seed):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.randn(*shape).astype(np.float32))


@pytest.mark.parametrize("variant", [v for v in model.MATMUL_VARIANTS[:4]])
def test_mlp_matches_ref(variant):
    b, d, h = 64, 128, 256
    if b % variant["bm"] or d % variant["bn"] or d % variant["bk"]:
        pytest.skip("tile does not divide this test shape")
    x = _rand((b, d), 0)
    w1 = _rand((d, h), 1)
    b1 = _rand((h,), 2)
    w2 = _rand((h, d), 3)
    b2 = _rand((d,), 4)
    got = model.mlp(x, w1, b1, w2, b2, **variant)
    want = ref.mlp_ref(x, w1, b1, w2, b2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(
    c=st.sampled_from([3, 8]),
    cout=st.sampled_from([8, 16]),
    hw=st.sampled_from([8, 14]),
    stride=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**16),
)
def test_conv_block_matches_lax_conv(c, cout, hw, stride, seed):
    x = _rand((1, c, hw, hw), seed)
    w = _rand((cout, c, 3, 3), seed + 1)
    got = model.conv_block(x, w, stride=stride, pad=1, bm=8, bn=8, bk=8)
    want = ref.conv2d_ref(x, w, stride=stride, pad=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_im2col_shape():
    x = _rand((2, 3, 8, 8), 0)
    patches, (n, oh, ow) = model.im2col(x, 3, 3, stride=1, pad=1)
    assert (n, oh, ow) == (2, 8, 8)
    assert patches.shape == (2 * 8 * 8, 3 * 3 * 3)


def test_exported_variants_all_divide_matmul_shape():
    m, n, k = model.MATMUL_SHAPE
    for v in model.MATMUL_VARIANTS:
        assert m % v["bm"] == 0 and n % v["bn"] == 0 and k % v["bk"] == 0, v
