"""Kernel-vs-reference correctness: the core L1 signal.

Hypothesis sweeps shapes/tiles/dtypes for the tiled matmul; fixed cases
cover the epilogue kernel and edge tiles.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.matmul_tiled import matmul_tiled, vmem_footprint_bytes
from compile.kernels.bias_relu import bias_relu
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def _rand(shape, dtype, seed):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.randn(*shape).astype(dtype))


# hypothesis: tile sizes drawn from divisor-friendly sets
tiles = st.sampled_from([8, 16, 32])
mults = st.integers(min_value=1, max_value=4)


@settings(max_examples=25, deadline=None)
@given(bm=tiles, bn=tiles, bk=tiles, am=mults, an=mults, ak=mults, seed=st.integers(0, 2**16))
def test_matmul_matches_ref_under_any_tiling(bm, bn, bk, am, an, ak, seed):
    m, n, k = bm * am, bn * an, bk * ak
    x = _rand((m, k), np.float32, seed)
    w = _rand((k, n), np.float32, seed + 1)
    got = matmul_tiled(x, w, bm=bm, bn=bn, bk=bk)
    want = ref.matmul_ref(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("variant", [
    dict(bm=8, bn=8, bk=8),
    dict(bm=16, bn=32, bk=64),
    dict(bm=64, bn=64, bk=64),
])
def test_matmul_exported_variants(variant):
    m = n = k = 128
    x = _rand((m, k), np.float32, 7)
    w = _rand((k, n), np.float32, 8)
    got = matmul_tiled(x, w, **variant)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.matmul_ref(x, w)),
                               rtol=1e-5, atol=1e-5)


def test_matmul_single_tile_equals_problem():
    # degenerate schedule: one grid step
    x = _rand((16, 16), np.float32, 1)
    w = _rand((16, 16), np.float32, 2)
    got = matmul_tiled(x, w, bm=16, bn=16, bk=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x) @ np.asarray(w),
                               rtol=1e-5, atol=1e-5)


def test_matmul_rejects_nondivisible_tiles():
    x = _rand((30, 30), np.float32, 3)
    w = _rand((30, 30), np.float32, 4)
    with pytest.raises(AssertionError):
        matmul_tiled(x, w, bm=16, bn=16, bk=16)


def test_matmul_identity():
    x = _rand((32, 32), np.float32, 5)
    eye = jnp.eye(32, dtype=jnp.float32)
    got = matmul_tiled(x, eye, bm=16, bn=16, bk=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x), rtol=1e-6, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(bm=st.sampled_from([8, 16, 32]), rows=mults, n=st.sampled_from([16, 64, 256]),
       seed=st.integers(0, 2**16))
def test_bias_relu_matches_ref(bm, rows, n, seed):
    m = bm * rows
    x = _rand((m, n), np.float32, seed)
    b = _rand((n,), np.float32, seed + 1)
    got = bias_relu(x, b, bm=bm)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.bias_relu_ref(x, b)),
                               rtol=1e-6, atol=1e-6)


def test_bias_relu_clamps_negative():
    x = jnp.full((8, 4), -5.0, jnp.float32)
    b = jnp.zeros((4,), jnp.float32)
    got = bias_relu(x, b, bm=8)
    assert float(jnp.max(got)) == 0.0


def test_vmem_footprint_monotone():
    assert vmem_footprint_bytes(8, 8, 8) < vmem_footprint_bytes(64, 64, 64)
    # the biggest exported variant stays under 16 MiB VMEM
    assert vmem_footprint_bytes(128, 128, 64) < 16 * 1024 * 1024
