"""AOT path tests: lowering to HLO text must succeed and be loadable-shaped.

These don't run the Rust side (cargo tests do); they validate that the
artifacts the Makefile produces are well-formed: non-empty HLO text with
an ENTRY computation, a consistent manifest, and deterministic output.
"""

import json
import os

import jax
import pytest

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")


def test_matmul_lowers_to_hlo_text():
    lowered, shapes = aot.lower_matmul(model.MATMUL_VARIANTS[1])
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "f32[256,256]" in text
    assert shapes == [(256, 256), (256, 256)]


def test_mlp_lowers_to_hlo_text():
    variant = dict(bm=32, bn=32, bk=32)
    lowered, shapes = aot.lower_mlp(variant)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    b, d, h = model.MLP_SHAPE
    assert f"f32[{b},{d}]" in text
    assert len(shapes) == 5


def test_lowering_is_deterministic():
    v = model.MATMUL_VARIANTS[0]
    a = aot.to_hlo_text(aot.lower_matmul(v)[0])
    b = aot.to_hlo_text(aot.lower_matmul(v)[0])
    assert a == b


def test_main_writes_manifest(tmp_path):
    import sys
    argv = sys.argv
    sys.argv = ["aot.py", "--out-dir", str(tmp_path)]
    try:
        aot.main()
    finally:
        sys.argv = argv
    with open(tmp_path / "manifest.json") as f:
        manifest = json.load(f)
    arts = manifest["artifacts"]
    # every matmul variant exported; mlp only for divisible tiles
    matmuls = [a for a in arts if a["kind"] == "matmul"]
    assert len(matmuls) == len(model.MATMUL_VARIANTS)
    for a in arts:
        path = tmp_path / a["path"]
        assert path.exists() and os.path.getsize(path) > 100, a
        assert a["schedule"].startswith("bm")
        assert all(isinstance(s, list) for s in a["inputs"])


@pytest.mark.parametrize("variant", model.MATMUL_VARIANTS)
def test_tags_unique(variant):
    tags = [aot.tag_of(v) for v in model.MATMUL_VARIANTS]
    assert len(set(tags)) == len(tags)
