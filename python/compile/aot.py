"""AOT lowering: JAX (L2) + Pallas (L1) -> HLO text artifacts for Rust.

Run once by ``make artifacts``. Emits, per schedule variant:

* ``artifacts/matmul_<tag>.hlo.txt``  — the tiled GEMM kernel alone
* ``artifacts/mlp_<tag>.hlo.txt``     — the two-layer MLP block
* ``artifacts/manifest.json``         — names, paths, schedules, shapes

HLO **text** (not ``.serialize()``) is the interchange format: jax >= 0.5
emits protos with 64-bit instruction ids which the runtime's
xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_matmul(variant):
    m, n, k = model.MATMUL_SHAPE
    x = jax.ShapeDtypeStruct((m, k), jnp.float32)
    w = jax.ShapeDtypeStruct((k, n), jnp.float32)

    def fn(x, w):
        return (model.matmul_tiled(x, w, **variant),)

    return jax.jit(fn).lower(x, w), [(m, k), (k, n)]


def lower_mlp(variant):
    b, d, h = model.MLP_SHAPE
    x = jax.ShapeDtypeStruct((b, d), jnp.float32)
    w1 = jax.ShapeDtypeStruct((d, h), jnp.float32)
    b1 = jax.ShapeDtypeStruct((h,), jnp.float32)
    w2 = jax.ShapeDtypeStruct((h, d), jnp.float32)
    b2 = jax.ShapeDtypeStruct((d,), jnp.float32)

    def fn(x, w1, b1, w2, b2):
        return (model.mlp(x, w1, b1, w2, b2, **variant),)

    return jax.jit(fn).lower(x, w1, b1, w2, b2), [(b, d), (d, h), (h,), (h, d), (d,)]


def tag_of(variant) -> str:
    return f"bm{variant['bm']}_bn{variant['bn']}_bk{variant['bk']}"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    entries = []
    for variant in model.MATMUL_VARIANTS:
        tag = tag_of(variant)
        for kind, lower in [("matmul", lower_matmul), ("mlp", lower_mlp)]:
            # mlp shapes don't fit the largest tiles; skip invalid combos
            if kind == "mlp":
                b, d, h = model.MLP_SHAPE
                if b % variant["bm"] or d % variant["bn"] or d % variant["bk"]:
                    continue
            lowered, shapes = lower(variant)
            text = to_hlo_text(lowered)
            name = f"{kind}_{tag}"
            path = f"{name}.hlo.txt"
            with open(os.path.join(args.out_dir, path), "w") as f:
                f.write(text)
            entries.append(
                dict(name=name, path=path, schedule=tag, kind=kind,
                     inputs=[list(s) for s in shapes])
            )
            print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump({"artifacts": entries}, f, indent=1)
    print(f"manifest: {len(entries)} artifacts")


if __name__ == "__main__":
    main()
