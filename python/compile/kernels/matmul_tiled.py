"""L1 Pallas kernel: tiled matmul with parametric block sizes.

This is the executable realization of Tuna's schedule choice: the (bm, bn,
bk) block shape corresponds to the (tile_m, tile_n, tile_k) knobs of the
Rust-side CPU matmul template, expressed TPU-style — the tiles become
`BlockSpec` block shapes (the VMEM working set, standing in for the L1
footprint the paper's cache model bounds), the grid walks (m/bm, n/bn,
k/bk) exactly like the outer tile loops, and the inner `jnp.dot` maps onto
the MXU. See DESIGN.md §Hardware-Adaptation.

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; interpret mode lowers to plain HLO that both pytest (via
jax) and the Rust runtime (via PJRT) execute with identical numerics.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, o_ref, acc_ref, *, nsteps_k):
    """One (bm, bn) output tile: accumulate x_tile @ w_tile over the k grid.

    A float32 VMEM scratch accumulator keeps partial sums at full precision
    regardless of the output dtype (the standard Pallas matmul pattern).
    """

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros(acc_ref.shape, jnp.float32)

    acc_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(pl.program_id(2) == nsteps_k - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul_tiled(x, w, *, bm=32, bn=32, bk=32):
    """`x @ w` under an explicit (bm, bn, bk) tiling schedule.

    Block sizes must divide the problem sizes — the Rust search space only
    proposes divisors, mirroring AutoTVM's split candidates.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"tiles ({bm},{bn},{bk}) must divide problem ({m},{n},{k})"
    )
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_kernel, nsteps_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, ks: (i, ks)),
            pl.BlockSpec((bk, bn), lambda i, j, ks: (ks, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, ks: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=True,
    )(x, w)


def vmem_footprint_bytes(bm, bn, bk, dtype_bytes=4):
    """Static VMEM working-set estimate for a schedule (DESIGN.md §Perf):
    x tile + w tile + output tile + f32 accumulator."""
    return dtype_bytes * (bm * bk + bk * bn + bm * bn) + 4 * bm * bn
