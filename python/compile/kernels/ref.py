"""Pure-jnp correctness oracles for the Pallas kernels.

Every kernel in this package has a reference here; pytest (and the
hypothesis sweeps) assert allclose between the two. The references use
only standard jax.numpy / lax ops so they exercise an entirely different
code path from the Pallas lowering.
"""

import jax.numpy as jnp
from jax import lax


def matmul_ref(x, w):
    """Plain `x @ w` in f32 accumulation."""
    return jnp.dot(
        x.astype(jnp.float32), w.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ).astype(x.dtype)


def bias_relu_ref(x, b):
    return jnp.maximum(x + b[None, :], 0.0)


def mlp_ref(x, w1, b1, w2, b2):
    """Two-layer MLP: relu(x@w1 + b1) @ w2 + b2."""
    h = bias_relu_ref(matmul_ref(x, w1), b1)
    return matmul_ref(h, w2) + b2[None, :]


def conv2d_ref(x_nchw, w_oihw, stride=1, pad=1):
    """NCHW direct convolution via lax.conv (the oracle for the im2col +
    tiled-matmul path in model.py)."""
    return lax.conv_general_dilated(
        x_nchw,
        w_oihw,
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
