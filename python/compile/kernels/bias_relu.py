"""L1 Pallas kernel: fused bias + ReLU epilogue.

The elementwise epilogue that follows every dense layer in the L2 model.
Row-tiled so each grid step streams one block through VMEM — the TPU
analogue of keeping the epilogue fused into the producer's cache tile
(Tuna's cache model rewards exactly this fusion on CPU).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, b_ref, o_ref):
    o_ref[...] = jnp.maximum(x_ref[...] + b_ref[...], 0.0)


@functools.partial(jax.jit, static_argnames=("bm",))
def bias_relu(x, b, *, bm=32):
    """`max(x + b, 0)` with `b` broadcast over rows; row-block size bm."""
    m, n = x.shape
    assert b.shape == (n,), f"bias shape {b.shape} != ({n},)"
    assert m % bm == 0, f"bm={bm} must divide m={m}"
    return pl.pallas_call(
        _kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, b)
