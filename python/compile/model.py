"""L2: the JAX compute graph, built on the L1 Pallas kernels.

Two model entry points, both schedule-parametric (the (bm, bn, bk) tiles
are the knobs Tuna's Rust-side search chooses):

* ``mlp`` — a two-layer MLP block (the BERT FFN shape family): both
  matmuls run through the tiled Pallas kernel, the epilogue through the
  fused bias+relu kernel.
* ``conv_block`` — an im2col convolution: patch extraction stays in jnp
  (layout transform), the GEMM — the compute hot-spot — runs through the
  same tiled kernel, mirroring how the Rust templates treat conv as a
  blocked contraction.

Python here is build-time only: ``aot.py`` lowers these functions to HLO
text once, and the Rust runtime executes the artifacts via PJRT.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels.bias_relu import bias_relu
from .kernels.matmul_tiled import matmul_tiled


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def mlp(x, w1, b1, w2, b2, *, bm=32, bn=32, bk=32):
    """relu(x@w1 + b1) @ w2 + b2 under one tiling schedule."""
    h = matmul_tiled(x, w1, bm=bm, bn=bn, bk=bk)
    h = bias_relu(h, b1, bm=bm)
    out = matmul_tiled(h, w2, bm=bm, bn=bn, bk=bk)
    return out + b2[None, :]


def im2col(x_nchw, kh, kw, stride=1, pad=1):
    """Unfold NCHW input into (N*OH*OW, CIN*KH*KW) patches (jnp-only —
    a layout transform, not the hot-spot)."""
    n, c, h, w = x_nchw.shape
    xp = jnp.pad(x_nchw, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
    oh = (h + 2 * pad - kh) // stride + 1
    ow = (w + 2 * pad - kw) // stride + 1
    cols = []
    for i in range(kh):
        for j in range(kw):
            patch = xp[:, :, i : i + stride * oh : stride, j : j + stride * ow : stride]
            cols.append(patch.reshape(n, c, oh * ow))
    # (n, c*kh*kw, oh*ow) -> (n*oh*ow, c*kh*kw)
    stacked = jnp.concatenate(cols, axis=1)
    return stacked.transpose(0, 2, 1).reshape(n * oh * ow, c * kh * kw), (n, oh, ow)


@functools.partial(jax.jit, static_argnames=("stride", "pad", "bm", "bn", "bk"))
def conv_block(x_nchw, w_oihw, *, stride=1, pad=1, bm=32, bn=32, bk=32):
    """NCHW conv as im2col + tiled-Pallas GEMM; returns NCHW."""
    cout, cin, kh, kw = w_oihw.shape
    patches, (n, oh, ow) = im2col(x_nchw, kh, kw, stride, pad)
    # im2col lays patches out (kh, kw, cin)-major along the contraction dim
    wmat = w_oihw.transpose(2, 3, 1, 0).reshape(kh * kw * cin, cout)
    m, k = patches.shape
    # pad GEMM dims up to tile multiples (zero rows/cols are exact)
    pm, pn, pk = (-m) % bm, (-cout) % bn, (-k) % bk
    patches = jnp.pad(patches, ((0, pm), (0, pk)))
    wmat = jnp.pad(wmat, ((0, pk), (0, pn)))
    out = matmul_tiled(patches, wmat, bm=bm, bn=bn, bk=bk)
    out = out[:m, :cout]
    return out.reshape(n, oh * ow, cout).transpose(0, 2, 1).reshape(n, cout, oh, ow)


#: The schedule variants aot.py exports — a slice through the Rust matmul
#: space (tile_m × tile_n × tile_k), from deliberately-poor to good, so the
#: e2e example can check Tuna's static ranking against real execution.
MATMUL_VARIANTS = [
    dict(bm=8, bn=8, bk=8),
    dict(bm=16, bn=16, bk=16),
    dict(bm=32, bn=32, bk=32),
    dict(bm=64, bn=64, bk=32),
    dict(bm=64, bn=64, bk=64),
    dict(bm=128, bn=128, bk=64),
]

#: Problem sizes exported for the runtime (BERT FFN-ish + square GEMM).
MATMUL_SHAPE = (256, 256, 256)
MLP_SHAPE = (128, 256, 512)  # (batch, d_in/d_out, d_hidden)
