//! Cross-compilation demo: optimize the same operator for all five targets
//! from one host, with zero target-device access — the capability dynamic
//! tuners structurally cannot offer.
//!
//! ```bash
//! cargo run --release --example cross_compile
//! ```
//!
//! Also demonstrates the cost-model *transferability* claim (paper §III):
//! the Graviton2-calibrated model applied unmodified to the Cortex-A53
//! (same NEON SIMD instruction set) still ranks schedules usefully.

use tuna::coordinator::{calibrate, Coordinator, Strategy};
use tuna::isa::TargetKind;
use tuna::search::EsParams;
use tuna::tir::ops::{Epilogue, OpSpec};
use tuna::util::stats::spearman;

fn main() {
    let op = OpSpec::Conv2d {
        n: 1, cin: 128, h: 28, w: 28, cout: 128, kh: 3, kw: 3, stride: 1, pad: 1,
        epilogue: Epilogue::None,
    };
    println!("cross-compiling {op} for every target from this host\n");
    println!(
        "{:<55} {:>11} {:>9} {:>8}",
        "target", "latency ms", "wall s", "device s"
    );
    for kind in TargetKind::ALL {
        let coord = Coordinator::new(kind);
        let es = EsParams { population: 24, iterations: 8, ..Default::default() };
        let r = coord.tune_op(&op, &Strategy::TunaStatic(es));
        println!(
            "{:<55} {:>11.3} {:>9.2} {:>8.1}",
            kind.display_name(),
            r.latency_s * 1e3,
            r.wall_s,
            r.device_s
        );
    }

    // --- transferability: Graviton2 coefficients on the A53 ---
    println!("\n== cost-model transferability (NEON -> NEON) ==");
    let g2_model = calibrate::calibrated_model(TargetKind::Graviton2);
    let a53_model = calibrate::calibrated_model(TargetKind::CortexA53);
    let a53_coord = Coordinator::new(TargetKind::CortexA53);
    // transplant Graviton2 coefficients onto the A53 feature extraction
    let transplanted = tuna::analysis::CostModel::with_coeffs(
        TargetKind::CortexA53,
        g2_model.coeffs().to_vec(),
    );
    let space = tuna::transform::config_space(&op, TargetKind::CortexA53);
    let mut native = Vec::new();
    let mut transferred = Vec::new();
    let mut truth = Vec::new();
    for i in 0..space.size().min(40) {
        let cfg = space.from_index(i);
        native.push(a53_model.predict(&op, &cfg));
        transferred.push(transplanted.predict(&op, &cfg));
        truth.push(a53_coord.device.run(&op, &cfg).seconds);
    }
    println!(
        "rank correlation with A53 ground truth: native {:.3}, Graviton2-transferred {:.3}",
        spearman(&native, &truth),
        spearman(&transferred, &truth)
    );
    println!("(close values = one NEON cost model serves both microarchitectures)");
}
