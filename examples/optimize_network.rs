//! The mandated end-to-end driver: run the whole Tuna pipeline on a real
//! workload (ResNet-50's operator inventory) and report the paper's
//! headline metrics — compile-time speedup vs AutoTVM and retained
//! performance vs full tuning.
//!
//! ```bash
//! cargo run --release --example optimize_network [-- <network> <target>]
//! ```
//!
//! Pipeline exercised end to end: network graph → unique-task extraction →
//! per-op schedule spaces → ES search over the calibrated static cost
//! model (Tuna) / measured tuning on the device simulator (AutoTVM full +
//! equal-budget partial) / vendor defaults (Framework) → schedule cache →
//! whole-network latency aggregation → Table-I/II-style report.

use tuna::config::parse_targets;
use tuna::coordinator::{Coordinator, Strategy};
use tuna::graph::all_networks;
use tuna::search::EsParams;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let net_name = args.first().map(String::as_str).unwrap_or("resnet50");
    let target = args
        .get(1)
        .map(|s| parse_targets(s).expect("bad target")[0])
        .unwrap_or(tuna::isa::TargetKind::Graviton2);

    let net = all_networks()
        .into_iter()
        .find(|n| n.name == net_name)
        .expect("unknown network (ssd_mobilenet|ssd_inception|resnet50|bert_base)");

    println!("network : {} ({:.2} GFLOP/inference)", net.display, net.flops() as f64 / 1e9);
    println!("target  : {}", target.display_name());
    println!("tasks   : {} unique operators\n", net.unique_tasks().len());

    let coord = Coordinator::new(target);

    // --- Tuna: static, parallel, deviceless ---
    let es = EsParams { population: 24, iterations: 10, ..Default::default() };
    let tuna = coord.tune_network(&net, &Strategy::TunaStatic(es.clone()));
    println!(
        "[tuna]            latency {:>9.2} ms   compile {:>9.2}s  (all wall-clock, device idle)",
        tuna.latency_s * 1e3,
        tuna.compile_seconds()
    );

    // --- AutoTVM partial: same compile budget, but measurement-bound ---
    let budget = coord.partial_budget_per_op(&tuna);
    let partial = coord.tune_network(&net, &Strategy::AutoTvmPartial { budget_s: budget });
    println!(
        "[autotvm-partial] latency {:>9.2} ms   compile {:>9.2}s  ({} measurements)",
        partial.latency_s * 1e3,
        partial.compile_seconds(),
        partial.per_op.values().map(|r| r.evaluations).sum::<u64>()
    );

    // --- AutoTVM full ---
    let full = coord.tune_network(&net, &Strategy::AutoTvmFull { trials: 64 });
    println!(
        "[autotvm-full]    latency {:>9.2} ms   compile {:>9.2}s  ({} measurements)",
        full.latency_s * 1e3,
        full.compile_seconds(),
        full.per_op.values().map(|r| r.evaluations).sum::<u64>()
    );

    // --- Framework / vendor library ---
    let vendor = coord.tune_network(&net, &Strategy::Vendor);
    println!(
        "[framework]       latency {:>9.2} ms   compile {:>9.2}s",
        vendor.latency_s * 1e3,
        vendor.compile_seconds()
    );

    // --- headline metrics ---
    println!("\n== headline metrics (paper's claims in parentheses) ==");
    println!(
        "compile-time speedup vs AutoTVM-full : {:>8.0}x   (paper: 40-340x)",
        full.compile_seconds() / tuna.compile_seconds().max(1e-9)
    );
    println!(
        "retained performance vs full tuning  : {:>8.1}%   (paper: ~91.5%)",
        full.latency_s / tuna.latency_s * 100.0
    );
    println!(
        "speedup vs AutoTVM at equal budget   : {:>8.2}x   (paper: up to 11x)",
        partial.latency_s / tuna.latency_s
    );
    println!(
        "speedup vs framework/vendor          : {:>8.2}x   (paper: up to 17.3x, avg 1.54x)",
        vendor.latency_s / tuna.latency_s
    );

    // --- schedule cache: recompiling the same network is free ---
    let rerun = coord.tune_network(&net, &Strategy::TunaStatic(es));
    let (entries, hits, _) = coord.cache_stats();
    println!(
        "recompile via schedule cache         : {:>8.4}s   ({} tasks served from {} cached entries, {} hits)",
        rerun.compile_seconds(),
        rerun.cache_hits,
        entries,
        hits
    );
}
