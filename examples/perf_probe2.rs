use std::time::Instant;
use tuna::isa::TargetKind;
use tuna::tir::ops::{Epilogue, OpSpec};

fn main() {
    let kind = TargetKind::Graviton2;
    let op = OpSpec::Matmul { m: 256, n: 256, k: 256, epilogue: Epilogue::None };
    let space = tuna::transform::config_space(&op, kind);
    let cfg = space.from_index(9);
    let f = tuna::transform::apply(&op, kind, &cfg);
    let march = match kind.build() { tuna::isa::Target::Cpu(m) => m, _ => unreachable!() };
    let t = Instant::now(); let prog = tuna::codegen::lower_cpu(&f, &march);
    println!("codegen  {:.2} ms", t.elapsed().as_secs_f64()*1e3);
    let t = Instant::now(); let lm = tuna::analysis::loop_map::map_loops(&f, &prog);
    println!("loop_map {:.2} ms", t.elapsed().as_secs_f64()*1e3);
    // steady-state pipeline estimate
    let t = Instant::now();
    let mut pipe = 0f64;
    for (i, b) in prog.blocks.iter().enumerate() {
        if b.instrs.is_empty() { continue; }
        let once = tuna::analysis::ilp::schedule_block(b, &march).cycles as f64;
        let mut tb = b.clone(); tb.instrs.extend(b.instrs.iter().cloned());
        let twice = tuna::analysis::ilp::schedule_block(&tb, &march).cycles as f64;
        pipe += (twice - once).max(1.0) * lm.block_trips[i] as f64;
    }
    println!("pipeline {:.2} ms (cost {pipe:.0})", t.elapsed().as_secs_f64()*1e3);
    let bases: Vec<u64> = prog.tensors.iter().map(|x| x.base_addr).collect();
    let t = Instant::now();
    let mut cnt = 0u64;
    let _ = tuna::sim::trace::visit(&f, &bases, 200_000, &mut |_, _| { cnt += 1; });
    println!("trace-only {:.2} ms ({cnt} accesses)", t.elapsed().as_secs_f64()*1e3);
    let mut h = tuna::sim::cache_sim::Hierarchy::new(&march.l1d, &march.l2);
    let t = Instant::now();
    let _ = tuna::sim::trace::visit(&f, &bases, 200_000, &mut |a, _| { h.access(a); });
    println!("trace+cache {:.2} ms", t.elapsed().as_secs_f64()*1e3);
    let t = Instant::now();
    let _ = tuna::sim::cpu::simulate(&f, &prog, &march);
    println!("simulate total {:.2} ms", t.elapsed().as_secs_f64()*1e3);
}
