//! Three-layer end-to-end proof: L1 Pallas kernel + L2 JAX model, AOT-
//! lowered to HLO text by `make artifacts`, loaded and executed from the
//! L3 Rust side via PJRT — and cross-checked against Tuna's static model.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_pjrt
//! ```
//!
//! What it verifies:
//! 1. every matmul schedule variant produces numerically correct results
//!    (vs an f64 reference computed in Rust);
//! 2. real wall-clock differences between schedule variants exist;
//! 3. Tuna's static scores rank the variants consistently with reality
//!    (Spearman correlation + regret of the top static pick).

#[cfg(feature = "pjrt")]
fn main() {
    let dir = tuna::runtime::artifacts_dir();
    if let Err(e) = tuna::runtime::e2e::run(&dir, 5) {
        eprintln!("e2e failed: {e:#}");
        std::process::exit(1);
    }
}

#[cfg(not(feature = "pjrt"))]
fn main() {
    eprintln!("e2e_pjrt needs the PJRT runtime; rebuild with `--features pjrt`");
}
