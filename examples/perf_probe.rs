// quick hot-path probe
use std::time::Instant;
use tuna::isa::TargetKind;
use tuna::tir::ops::{Epilogue, OpSpec};
use tuna::sim::Device;

fn main() {
    let kind = TargetKind::Graviton2;
    let cm = tuna::analysis::CostModel::with_default_coeffs(kind);
    let ops = [
        OpSpec::Matmul { m: 256, n: 256, k: 256, epilogue: Epilogue::None },
        OpSpec::Conv2d {
            n: 1, cin: 64, h: 56, w: 56, cout: 64, kh: 3, kw: 3, stride: 1, pad: 1,
            epilogue: Epilogue::None,
        },
        OpSpec::DepthwiseConv2d {
            n: 1, c: 96, h: 112, w: 112, kh: 3, kw: 3, stride: 2, pad: 1,
            epilogue: Epilogue::None,
        },
    ];
    for op in &ops {
        let space = tuna::transform::config_space(op, kind);
        // static predict timing
        let t0 = Instant::now();
        let mut n = 0u32;
        for i in 0..space.size().min(40) {
            let cfg = space.from_index(i);
            let _ = cm.predict(op, &cfg);
            n += 1;
        }
        let per_pred = t0.elapsed().as_secs_f64() / n as f64;
        // device.run timing
        let d = Device::new(kind);
        let t1 = Instant::now();
        let mut m = 0u32;
        for i in 0..space.size().min(10) {
            let cfg = space.from_index(i);
            let _ = d.run(op, &cfg);
            m += 1;
        }
        let per_sim = t1.elapsed().as_secs_f64() / m as f64;
        println!("{op}: predict {:.2} ms/cand, sim {:.2} ms/meas", per_pred*1e3, per_sim*1e3);
    }
    // breakdown for conv: features phases
    let op = ops[1];
    let space = tuna::transform::config_space(&op, kind);
    let cfg = space.from_index(7);
    let f = tuna::transform::apply(&op, kind, &cfg);
    let tmarch = match kind.build() { tuna::isa::Target::Cpu(m) => m, _ => unreachable!() };
    let t = Instant::now(); let prog = tuna::codegen::lower_cpu(&f, &tmarch); println!("codegen {:.2} ms", t.elapsed().as_secs_f64()*1e3);
    let t = Instant::now(); let lm = tuna::analysis::loop_map::map_loops(&f, &prog); println!("loop_map {:.2} ms", t.elapsed().as_secs_f64()*1e3);
    let t = Instant::now(); let _ = tuna::analysis::cache::analyze(&f, 16*1024); println!("cache {:.2} ms", t.elapsed().as_secs_f64()*1e3);
    let t = Instant::now(); let _ = tuna::analysis::ilp::program_cost(&prog, &lm, &tmarch); println!("ilp {:.2} ms", t.elapsed().as_secs_f64()*1e3);
}
