//! Quickstart: optimize one operator with Tuna's static analysis.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Picks a ResNet-class conv2d, searches its schedule space with Evolution
//! Strategies over the static cost model (no device needed!), then — only
//! for reporting — checks the chosen schedule on the device simulator and
//! against the vendor-library default.

use tuna::coordinator::{Coordinator, Strategy};
use tuna::isa::TargetKind;
use tuna::search::EsParams;
use tuna::tir::ops::{Epilogue, OpSpec};

fn main() {
    let op = OpSpec::Conv2d {
        n: 1, cin: 64, h: 56, w: 56, cout: 64, kh: 3, kw: 3, stride: 1, pad: 1,
        epilogue: Epilogue::None,
    };
    let target = TargetKind::Graviton2;

    println!("operator : {op}");
    println!("target   : {}", target.display_name());
    let space = tuna::transform::config_space(&op, target);
    println!("schedule space: {} configurations", space.size());

    // 1. Tuna: static search — no hardware, parallel across host threads.
    let coord = Coordinator::new(target);
    let es = EsParams { population: 32, iterations: 12, ..Default::default() };
    let tuna = coord.tune_op(&op, &Strategy::TunaStatic(es));
    println!(
        "\nTuna static search: {} candidates analyzed in {:.2}s wall, 0s device time",
        tuna.evaluations, tuna.wall_s
    );

    // 2. Baseline: the fixed vendor-library schedule.
    let vendor = coord.tune_op(&op, &Strategy::Vendor);

    // 3. Report (simulated deployment latency).
    let gflops = |s: f64| op.flops() as f64 / s / 1e9;
    println!("\n{:<24} {:>12} {:>12}", "schedule", "latency ms", "GFLOP/s");
    println!(
        "{:<24} {:>12.3} {:>12.1}",
        "tuna (static search)",
        tuna.latency_s * 1e3,
        gflops(tuna.latency_s)
    );
    println!(
        "{:<24} {:>12.3} {:>12.1}",
        "vendor default",
        vendor.latency_s * 1e3,
        gflops(vendor.latency_s)
    );
    println!(
        "\nspeedup over vendor: {:.2}x",
        vendor.latency_s / tuna.latency_s
    );

    // show what was chosen
    println!("\nchosen knobs:");
    for (knob, &choice) in space.knobs.iter().zip(&tuna.chosen.choices) {
        println!("  {:<12} = {:?}", knob.name, knob.values[choice]);
    }
}
