use std::time::Instant;
use tuna::isa::TargetKind;
use tuna::tir::ops::{Epilogue, OpSpec};
fn main() {
    let kind = TargetKind::XeonPlatinum8124M;
    for op in [
        OpSpec::Conv2dWinograd { n:1, cin:64, h:56, w:56, cout:64 },
        OpSpec::Conv2d {
            n: 1, cin: 64, h: 56, w: 56, cout: 64, kh: 3, kw: 3, stride: 1, pad: 1,
            epilogue: Epilogue::None,
        },
    ] {
        let cm = tuna::analysis::CostModel::with_default_coeffs(kind);
        let space = tuna::transform::config_space(&op, kind);
        let t0 = Instant::now();
        for i in 0..10 { let _ = cm.predict(&op, &space.from_index(i * space.size() / 10)); }
        println!("{op}: predict {:.1} ms", t0.elapsed().as_secs_f64()*1e3/10.0);
        let d = tuna::sim::Device::new(kind);
        let t0 = Instant::now();
        for i in 0..5 { let _ = d.run(&op, &space.from_index(i * space.size() / 5)); }
        println!("{op}: sim {:.1} ms", t0.elapsed().as_secs_f64()*1e3/5.0);
    }
}
